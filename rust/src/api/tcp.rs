//! The JSONL TCP surface of `synperf serve --tcp ADDR`: the same wire as
//! [`super::stdio`] (same classifier, same codecs — response bytes are
//! identical for the same request stream), served to **many concurrent
//! clients** with fair admission and fault isolation:
//!
//! - **Fair admission.** Each connection gets a bounded inbox of parsed
//!   lines; one shared dispatcher round-robins over the inboxes, admitting
//!   at most one request per connection per sweep into the coordinator
//!   queue. A client that floods cannot starve one that trickles — the
//!   flooder fills its own inbox and blocks (per-client backpressure)
//!   while the round-robin keeps serving everyone else.
//! - **Two-level backpressure.** The per-connection inbox bounds what one
//!   client can buffer; the coordinator's bounded queue bounds the total.
//!   A request that cannot be admitted before its deadline (its own
//!   `deadline_ms`, or [`TcpConfig::admit_timeout`] without one) answers
//!   the typed `deadline_exceeded` / `queue_full` error — never a hang.
//! - **Per-connection order.** Responses on a connection are written in
//!   that connection's input order by a dedicated writer thread draining a
//!   bounded window, exactly like the stdio surface's slot channel.
//! - **Fault quarantine.** Malformed and oversized lines answer typed
//!   errors; [`TcpConfig::quarantine_limit`] *consecutive* abusive lines
//!   disconnect the client after its error responses flush. Read timeouts
//!   tick the reader so half-open peers are reaped after
//!   [`TcpConfig::idle_timeout`] without progress (a slow-loris peer that
//!   trickles bytes counts as progress but can never exceed
//!   [`serve::MAX_LINE_BYTES`] of buffered line). Write timeouts bound a
//!   stuck consumer. No peer behavior panics the server.
//! - **Graceful drain.** When `shutdown` flips, the listener stops
//!   accepting, readers stop consuming input, every admitted request
//!   finishes and flushes, and [`serve`] joins all threads and returns.
//!
//! Everything is std-only: scoped threads, `Mutex`/`Condvar` queues
//! ([`crate::coordinator::queue::Bounded`]), and socket timeouts as ticks.

use super::serve::{self, LineReader, Parsed, ReadLine};
use super::wire;
use super::{PredictError, PredictRequest, PredictResponse};
use crate::autotune::{self, TuneError, TuneSpec};
use crate::coordinator::queue::{Bounded, Pop, PushError};
use crate::coordinator::{Client, Pending};
use crate::scenario::wire::SimulateRequest;
use crate::scenario::{self, ScenarioError, Simulator};
use crate::sweep::{self, SweepError, SweepRequest};
use std::collections::HashMap;
use std::io::{BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tuning knobs of the TCP surface. The defaults suit an interactive
/// deployment; tests shrink them to provoke every limit deterministically.
#[derive(Debug, Clone)]
pub struct TcpConfig {
    /// Concurrent connections accepted; one over the limit is answered a
    /// single `queue_full` error line and dropped.
    pub max_clients: usize,
    /// Parsed-line inbox per connection (per-client backpressure bound).
    pub inbox_cap: usize,
    /// In-flight response window per connection (memory bound, same role
    /// as `max_inflight` on the stdio surface).
    pub max_inflight: usize,
    /// Consecutive malformed/oversized lines before the client is
    /// disconnected (after its error responses flush).
    pub quarantine_limit: u32,
    /// How long a request **without** `deadline_ms` may wait for queue
    /// admission before answering `queue_full`.
    pub admit_timeout: Duration,
    /// Reap a connection with no read progress for this long.
    pub idle_timeout: Duration,
    /// Bound on one blocked socket write (stuck consumer ⇒ disconnect).
    pub write_timeout: Duration,
    /// Poll granularity: read-timeout tick, inbox-push wait, accept poll.
    pub tick: Duration,
    /// Worker threads for sweep- and tune-verb lines (see
    /// [`sweep::run_request`] / [`autotune::run_tune`]).
    pub threads: usize,
}

impl Default for TcpConfig {
    fn default() -> TcpConfig {
        TcpConfig {
            max_clients: 64,
            inbox_cap: 64,
            max_inflight: 32,
            quarantine_limit: 8,
            admit_timeout: Duration::from_secs(2),
            idle_timeout: Duration::from_secs(60),
            write_timeout: Duration::from_secs(10),
            tick: Duration::from_millis(50),
            threads: 2,
        }
    }
}

/// Final tallies [`serve`] returns after drain (the `stats` verb reports
/// the same counters live, mid-run).
#[derive(Debug, Default, Clone, Copy)]
pub struct NetStats {
    pub served: u64,
    pub errors: u64,
    pub simulated: u64,
    pub swept: u64,
    pub tuned: u64,
    pub stats_lines: u64,
    pub oversized: u64,
    /// Connections accepted over the lifetime (including refused-at-cap).
    pub connections: u64,
    pub quarantined: u64,
    pub idle_reaped: u64,
    /// Write failures, read errors, and at-capacity refusals.
    pub disconnects: u64,
}

/// Lock-free server counters — the `stats` verb reads these mid-run
/// without taking any lock shared with the serving path.
#[derive(Default)]
struct NetCounters {
    served: AtomicU64,
    errors: AtomicU64,
    simulated: AtomicU64,
    swept: AtomicU64,
    tuned: AtomicU64,
    stats_lines: AtomicU64,
    oversized: AtomicU64,
    connections: AtomicU64,
    live: AtomicU64,
    quarantined: AtomicU64,
    idle_reaped: AtomicU64,
    disconnects: AtomicU64,
}

impl NetCounters {
    fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> NetStats {
        let get = |f: &AtomicU64| f.load(Ordering::Relaxed);
        NetStats {
            served: get(&self.served),
            errors: get(&self.errors),
            simulated: get(&self.simulated),
            swept: get(&self.swept),
            tuned: get(&self.tuned),
            stats_lines: get(&self.stats_lines),
            oversized: get(&self.oversized),
            connections: get(&self.connections),
            quarantined: get(&self.quarantined),
            idle_reaped: get(&self.idle_reaped),
            disconnects: get(&self.disconnects),
        }
    }

    fn client_stats(&self) -> wire::ClientStats {
        let get = |f: &AtomicU64| f.load(Ordering::Relaxed);
        wire::ClientStats {
            connected: get(&self.live),
            total: get(&self.connections),
            quarantined: get(&self.quarantined),
            idle_reaped: get(&self.idle_reaped),
            oversized_lines: get(&self.oversized),
            disconnects: get(&self.disconnects),
        }
    }
}

/// One parsed input line riding a connection's inbox, stamped with its
/// arrival time (deadlines are measured from **arrival**, so time a
/// request spends waiting in its inbox counts against its deadline).
struct Item {
    arrived: Instant,
    line: Line,
}

enum Line {
    Text(Parsed),
    Oversized(usize),
}

/// One in-flight response in a connection's window — mirrors the stdio
/// surface's slot type; the writer thread answers these in order.
enum Slot {
    Queued(Option<String>, Pending),
    Ready(Option<String>, Result<PredictResponse, PredictError>),
    Oversized(usize),
    Simulate(Option<String>, Result<SimulateRequest, ScenarioError>),
    Sweep(Option<String>, Result<SweepRequest, SweepError>),
    Tune(Option<String>, Result<TuneSpec, TuneError>),
    Stats(Option<String>),
}

/// Per-connection shared state: the reader thread produces into `inbox`,
/// the dispatcher moves admitted work into `window`, the writer drains it.
struct Conn {
    id: u64,
    inbox: Bounded<Item>,
    window: Bounded<Slot>,
    /// Set by the writer on write failure (or the reader on reap): the
    /// other two parties stop touching the socket and unwind.
    dead: AtomicBool,
}

/// A head-of-line predict request bounced off the full coordinator queue,
/// held by the dispatcher until space frees or its deadline expires.
struct ParkedReq {
    id: Option<String>,
    req: PredictRequest,
    arrived: Instant,
}

enum Admit {
    Slot(Slot),
    Park(ParkedReq),
}

/// One admission attempt for a parked predict request. `try_predict_silent`
/// keeps per-attempt retries out of the rejection metrics; only the
/// terminal outcome is recorded.
fn admit(client: &Client, p: ParkedReq, cfg: &TcpConfig) -> Admit {
    match client.try_predict_silent(p.req.clone()) {
        Ok(pending) => Admit::Slot(Slot::Queued(p.id, pending)),
        Err(PredictError::QueueFull) => {
            let limit = match p.req.opts.deadline_ms {
                Some(ms) => Duration::from_millis(ms),
                None => cfg.admit_timeout,
            };
            if p.arrived.elapsed() < limit {
                return Admit::Park(p);
            }
            client.metrics().record_rejected();
            if p.req.opts.deadline_ms.is_some() {
                client.metrics().record_deadline_exceeded();
                Admit::Slot(Slot::Ready(p.id, Err(PredictError::DeadlineExceeded)))
            } else {
                Admit::Slot(Slot::Ready(p.id, Err(PredictError::QueueFull)))
            }
        }
        Err(e) => Admit::Slot(Slot::Ready(p.id, Err(e))),
    }
}

/// Serve the listener until `shutdown` flips, then drain: stop accepting,
/// stop reading, answer everything admitted, flush every connection, join
/// every thread. The `simulator` factory is shared by all connections
/// (each builds its own `Simulator` lazily on its writer thread — the
/// simulator itself never crosses threads).
pub fn serve<F>(
    listener: TcpListener,
    client: &Client,
    simulator: F,
    cfg: &TcpConfig,
    shutdown: &AtomicBool,
) -> std::io::Result<NetStats>
where
    F: Fn() -> Simulator + Sync,
{
    listener.set_nonblocking(true)?;
    let counters = NetCounters::default();
    let conns: Mutex<Vec<Arc<Conn>>> = Mutex::new(Vec::new());
    let accept_done = AtomicBool::new(false);
    let accept_err: Mutex<Option<std::io::Error>> = Mutex::new(None);
    let simulator = &simulator;
    let counters_ref = &counters;
    let conns_ref = &conns;

    std::thread::scope(|scope| {
        // ---- accept loop -------------------------------------------------
        let accepter = scope.spawn(move || {
            let mut next_id = 0u64;
            loop {
                if shutdown.load(Ordering::Acquire) {
                    break;
                }
                let stream = match listener.accept() {
                    Ok((stream, _)) => stream,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(cfg.tick.min(Duration::from_millis(25)));
                        continue;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        *accept_err.lock().unwrap() = Some(e);
                        break;
                    }
                };
                NetCounters::bump(&counters_ref.connections);
                let _ = stream.set_nodelay(true);
                if counters_ref.live.load(Ordering::Relaxed) >= cfg.max_clients as u64 {
                    // over capacity: one typed refusal line, then drop
                    NetCounters::bump(&counters_ref.disconnects);
                    let mut s = &stream;
                    let _ = writeln!(
                        s,
                        "{}",
                        wire::encode_response(None, &Err(PredictError::QueueFull))
                    );
                    continue;
                }
                let (rd, wr) = match (stream.try_clone(), stream) {
                    (Ok(rd), wr) => (rd, wr),
                    (Err(_), _) => {
                        NetCounters::bump(&counters_ref.disconnects);
                        continue;
                    }
                };
                let conn = Arc::new(Conn {
                    id: next_id,
                    inbox: Bounded::new(cfg.inbox_cap.max(1)),
                    window: Bounded::new(cfg.max_inflight.max(1)),
                    dead: AtomicBool::new(false),
                });
                next_id += 1;
                // register before spawning: the dispatcher's exit check
                // (`no conns && accept done`) can never miss a live one
                conns_ref.lock().unwrap().push(conn.clone());
                counters_ref.live.fetch_add(1, Ordering::Relaxed);
                let reader_conn = conn.clone();
                scope.spawn(move || read_loop(rd, &reader_conn, cfg, counters_ref, shutdown));
                scope.spawn(move || {
                    write_loop(wr, &conn, client, simulator, cfg, counters_ref)
                });
            }
            accept_done.store(true, Ordering::Release);
        });

        // ---- dispatcher: fair round-robin admission ----------------------
        dispatch_loop(client, cfg, conns_ref, counters_ref, &accept_done);
        accepter.join().expect("tcp accept thread");
    });

    if let Some(e) = accept_err.lock().unwrap().take() {
        return Err(e);
    }
    Ok(counters.snapshot())
}

/// Per-connection reader: capped line reads on a `tick` timeout, blank
/// lines skipped, one classify per line, quarantine on consecutive abuse,
/// idle-reap on stalled progress. Closes the inbox on exit — that is the
/// dispatcher's signal that this connection has no more input coming.
fn read_loop(
    stream: TcpStream,
    conn: &Conn,
    cfg: &TcpConfig,
    counters: &NetCounters,
    shutdown: &AtomicBool,
) {
    let _ = stream.set_read_timeout(Some(cfg.tick));
    let mut lines = LineReader::new(&stream, serve::MAX_LINE_BYTES);
    let mut last_progress = Instant::now();
    let mut last_pending = 0usize;
    let mut consecutive_bad = 0u32;
    'read: loop {
        if conn.dead.load(Ordering::Acquire) || shutdown.load(Ordering::Acquire) {
            break;
        }
        let line = match lines.read_line() {
            Err(_) => {
                // connection reset (possibly mid-line): unwind quietly
                NetCounters::bump(&counters.disconnects);
                break;
            }
            Ok(ReadLine::Eof) => break,
            Ok(ReadLine::Idle) => {
                // a trickling peer grows the partial line — that counts as
                // progress; a silent one is reaped after idle_timeout
                let pending = lines.pending();
                if pending != last_pending {
                    last_pending = pending;
                    last_progress = Instant::now();
                } else if last_progress.elapsed() >= cfg.idle_timeout {
                    NetCounters::bump(&counters.idle_reaped);
                    conn.dead.store(true, Ordering::Release);
                    break;
                }
                continue;
            }
            Ok(ReadLine::Oversized(n)) => {
                last_progress = Instant::now();
                last_pending = lines.pending();
                consecutive_bad += 1;
                Line::Oversized(n)
            }
            Ok(ReadLine::Line(text)) => {
                last_progress = Instant::now();
                last_pending = lines.pending();
                if text.trim().is_empty() {
                    continue;
                }
                let parsed = serve::classify(&text);
                if matches!(parsed, Parsed::Malformed(_)) {
                    consecutive_bad += 1;
                } else {
                    consecutive_bad = 0;
                }
                Line::Text(parsed)
            }
        };
        let mut item = Item { arrived: Instant::now(), line };
        // bounded push with a tick so a dead/draining connection unwinds
        loop {
            match conn.inbox.push_wait(item, Some(cfg.tick)) {
                Ok(()) => break,
                Err(PushError::Full(back)) => {
                    if conn.dead.load(Ordering::Acquire) || shutdown.load(Ordering::Acquire) {
                        break 'read;
                    }
                    item = back;
                }
                Err(PushError::Closed(_)) => break 'read,
            }
        }
        if consecutive_bad >= cfg.quarantine_limit {
            // the abusive peer gets its typed error responses, then EOF
            NetCounters::bump(&counters.quarantined);
            break;
        }
    }
    conn.inbox.close();
}

/// The shared dispatcher: round-robins over the live connections, moving
/// at most one inbox item per connection per sweep into its response
/// window — admission fairness is positional, not timing-based. Predict
/// lines go through the coordinator queue (parking the head-of-line
/// request while the queue is full); every other verb passes straight to
/// the window. Exits when the accept loop is done and every connection
/// has fully drained.
fn dispatch_loop(
    client: &Client,
    cfg: &TcpConfig,
    conns: &Mutex<Vec<Arc<Conn>>>,
    counters: &NetCounters,
    accept_done: &AtomicBool,
) {
    let mut parked: HashMap<u64, ParkedReq> = HashMap::new();
    loop {
        // read the flag BEFORE snapshotting: registration happens-before
        // the flag's store, so `done && empty` can never miss a connection
        let done = accept_done.load(Ordering::Acquire);
        let snapshot: Vec<Arc<Conn>> = conns.lock().unwrap().clone();
        if done && snapshot.is_empty() {
            break;
        }
        let mut progress = false;
        for conn in &snapshot {
            if conn.dead.load(Ordering::Acquire) {
                // writer failed or reader reaped: tear down both ends
                parked.remove(&conn.id);
                conn.inbox.close();
                conn.window.close();
                remove_conn(conns, counters, conn.id);
                progress = true;
                continue;
            }
            // head-of-line parked request first — order per connection
            if let Some(p) = parked.remove(&conn.id) {
                if conn.window.len() >= conn.window.capacity() {
                    parked.insert(conn.id, p);
                    continue;
                }
                match admit(client, p, cfg) {
                    Admit::Park(p) => {
                        parked.insert(conn.id, p);
                        continue; // still waiting: hold line order
                    }
                    Admit::Slot(slot) => {
                        let _ = conn.window.try_push(slot);
                        progress = true;
                        continue; // one item per conn per sweep
                    }
                }
            }
            if conn.window.len() >= conn.window.capacity() {
                continue; // writer backpressure: revisit next sweep
            }
            match conn.inbox.try_pop() {
                Pop::Timeout => {}
                Pop::Closed => {
                    // reader done and inbox drained: close the window so
                    // the writer flushes the tail and exits
                    conn.window.close();
                    remove_conn(conns, counters, conn.id);
                    progress = true;
                }
                Pop::Item(item) => {
                    progress = true;
                    let slot = match item.line {
                        Line::Oversized(n) => Some(Slot::Oversized(n)),
                        Line::Text(parsed) => match parsed {
                            Parsed::Malformed(why) => Some(Slot::Ready(
                                None,
                                Err(PredictError::UnsupportedKernel(why)),
                            )),
                            Parsed::Stats(id) => Some(Slot::Stats(id)),
                            Parsed::Sweep(id, spec) => Some(Slot::Sweep(id, spec)),
                            Parsed::Tune(id, spec) => Some(Slot::Tune(id, spec)),
                            Parsed::Simulate(id, req) => Some(Slot::Simulate(id, req)),
                            Parsed::Predict(id, Err(e)) => Some(Slot::Ready(id, Err(e))),
                            Parsed::Predict(id, Ok(req)) => {
                                let p = ParkedReq { id, req, arrived: item.arrived };
                                match admit(client, p, cfg) {
                                    Admit::Slot(slot) => Some(slot),
                                    Admit::Park(p) => {
                                        parked.insert(conn.id, p);
                                        None
                                    }
                                }
                            }
                        },
                    };
                    if let Some(slot) = slot {
                        let _ = conn.window.try_push(slot);
                    }
                }
            }
        }
        if !progress {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

fn remove_conn(conns: &Mutex<Vec<Arc<Conn>>>, counters: &NetCounters, id: u64) {
    let mut g = conns.lock().unwrap();
    let before = g.len();
    g.retain(|c| c.id != id);
    if g.len() < before {
        counters.live.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Per-connection writer: drains the window in order, flushing whenever no
/// further response is immediately ready (an interactive peer never waits
/// on a half-full buffer). On any write failure it marks the connection
/// dead and unwinds — the dispatcher tears the rest down.
fn write_loop<F>(
    stream: TcpStream,
    conn: &Conn,
    client: &Client,
    simulator: &F,
    cfg: &TcpConfig,
    counters: &NetCounters,
) where
    F: Fn() -> Simulator + Sync,
{
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let mut writer = BufWriter::new(stream);
    let mut sim: Option<Simulator> = None;
    loop {
        let slot = match conn.window.try_pop() {
            Pop::Item(slot) => slot,
            Pop::Closed => break,
            Pop::Timeout => {
                if writer.flush().is_err() {
                    break_dead(conn, counters);
                    break;
                }
                match conn.window.pop() {
                    Some(slot) => slot,
                    None => break,
                }
            }
        };
        let (id, res) = match slot {
            Slot::Queued(id, pending) => (id, pending.wait()),
            Slot::Ready(id, res) => (id, res),
            Slot::Oversized(n) => {
                NetCounters::bump(&counters.oversized);
                (None, Err(serve::oversized_error(n)))
            }
            Slot::Stats(id) => {
                // counted before assembly, so the report includes itself
                NetCounters::bump(&counters.served);
                NetCounters::bump(&counters.stats_lines);
                let s = counters.snapshot();
                let report = serve::build_stats(
                    client,
                    s.served,
                    s.errors,
                    s.simulated,
                    s.swept,
                    s.tuned,
                    counters.client_stats(),
                );
                let line = wire::encode_stats(id.as_deref(), &report);
                if writeln!(writer, "{line}").is_err() {
                    break_dead(conn, counters);
                    break;
                }
                continue;
            }
            Slot::Sweep(id, req) => {
                NetCounters::bump(&counters.served);
                NetCounters::bump(&counters.swept);
                let res =
                    req.and_then(|req| sweep::run_request(&req, simulator, cfg.threads));
                if res.is_err() {
                    NetCounters::bump(&counters.errors);
                }
                let line = sweep::wire::encode_sweep_response(id.as_deref(), &res);
                if writeln!(writer, "{line}").is_err() {
                    break_dead(conn, counters);
                    break;
                }
                continue;
            }
            Slot::Tune(id, spec) => {
                NetCounters::bump(&counters.served);
                NetCounters::bump(&counters.tuned);
                let res = spec.and_then(|spec| {
                    autotune::run_tune(&spec, autotune::Ceiling::auto, cfg.threads, |_| {})
                });
                if res.is_err() {
                    NetCounters::bump(&counters.errors);
                }
                let line = autotune::wire::encode_tune_response(id.as_deref(), &res);
                if writeln!(writer, "{line}").is_err() {
                    break_dead(conn, counters);
                    break;
                }
                continue;
            }
            Slot::Simulate(id, req) => {
                let sim = sim.get_or_insert_with(simulator);
                NetCounters::bump(&counters.served);
                NetCounters::bump(&counters.simulated);
                let line = match req {
                    Ok(SimulateRequest::Scenario(spec)) => {
                        let res = sim.simulate(&spec);
                        if res.is_err() {
                            NetCounters::bump(&counters.errors);
                        }
                        scenario::wire::encode_report(id.as_deref(), &res)
                    }
                    Ok(SimulateRequest::Cluster(spec)) => {
                        let res = sim.simulate_cluster(&spec);
                        if res.is_err() {
                            NetCounters::bump(&counters.errors);
                        }
                        scenario::wire::encode_cluster_report(id.as_deref(), &res)
                    }
                    Err(e) => {
                        NetCounters::bump(&counters.errors);
                        scenario::wire::encode_report(id.as_deref(), &Err(e))
                    }
                };
                if writeln!(writer, "{line}").is_err() {
                    break_dead(conn, counters);
                    break;
                }
                continue;
            }
        };
        NetCounters::bump(&counters.served);
        if res.is_err() {
            NetCounters::bump(&counters.errors);
        }
        if writeln!(writer, "{}", wire::encode_response(id.as_deref(), &res)).is_err() {
            break_dead(conn, counters);
            break;
        }
    }
    let _ = writer.flush();
}

fn break_dead(conn: &Conn, counters: &NetCounters) {
    NetCounters::bump(&counters.disconnects);
    conn.dead.store(true, Ordering::Release);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_bounded() {
        let cfg = TcpConfig::default();
        assert!(cfg.max_clients > 0 && cfg.inbox_cap > 0 && cfg.max_inflight > 0);
        assert!(cfg.quarantine_limit > 0);
        assert!(cfg.tick < cfg.idle_timeout);
    }

    #[test]
    fn counters_snapshot_round_trips() {
        let c = NetCounters::default();
        NetCounters::bump(&c.served);
        NetCounters::bump(&c.served);
        NetCounters::bump(&c.quarantined);
        c.live.fetch_add(3, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(s.served, 2);
        assert_eq!(s.quarantined, 1);
        let cs = c.client_stats();
        assert_eq!(cs.connected, 3);
        assert_eq!(cs.quarantined, 1);
    }
}
