//! **Prediction protocol v1** — the typed request/response surface every
//! prediction consumer in the tree speaks (paper §VI: the coordinator
//! answers kernel-latency queries for system-level exploration).
//!
//! Before this subsystem, every layer answered with a bare `f64` over an
//! unbounded channel: a caller could not tell a trained-MLP prediction from
//! a degraded roofline fallback, a cache hit from a miss, or a real failure
//! from a silent default. The protocol fixes that:
//!
//!  * [`PredictRequest`] — kernel config + GPU + a builder for the options
//!    (mean vs p80 ceiling flavor, strict vs allow-degraded, per-pipeline
//!    feature breakdown, trace tags);
//!  * [`PredictResponse`] — latency plus [`Provenance`] (`Mlp` vs
//!    `Roofline`, analysis-cache hit), the answering model [`Flavor`], and
//!    an optional [`Breakdown`];
//!  * [`PredictError`] — the **closed** error taxonomy (unknown GPU,
//!    unsupported kernel, predictor unavailable, queue full, shutdown)
//!    replacing stringly `anyhow` at every public edge.
//!
//! [`predict_batch`] / [`predict_one`] are the *only* code that routes
//! feature vectors into the per-category MLPs; the coordinator service, the
//! E2E evaluator, the experiments and the CLI all call through here, so
//! there is exactly one request path. The same protocol is exposed
//! externally as a JSONL wire surface ([`wire`], `synperf serve --stdio`;
//! line-delimited requests in, line-delimited responses out — [`stdio`]).

pub mod serve;
pub mod stdio;
pub mod tcp;
pub mod wire;

use crate::dataset::Sample;
use crate::engine::{par, Analysis, PredictionEngine};
use crate::features::FEATURE_DIM;
use crate::hw::{gpu_by_name, GpuSpec};
use crate::kernels::{KernelConfig, KernelKind};
use crate::mlp::Predictor;
use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::Arc;

/// Wire/API protocol version; bumped on incompatible schema changes.
pub const PROTOCOL_VERSION: u32 = 1;

/// Which trained model answers: the mean-accuracy SynPerf MLP or the
/// pinball-τ=0.8 "performance ceiling" model (§VII).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Flavor {
    Mean,
    P80,
}

impl Flavor {
    pub fn name(&self) -> &'static str {
        match self {
            Flavor::Mean => "mean",
            Flavor::P80 => "p80",
        }
    }

    pub fn from_name(s: &str) -> Option<Flavor> {
        match s {
            "mean" => Some(Flavor::Mean),
            "p80" => Some(Flavor::P80),
            _ => None,
        }
    }
}

/// Where a prediction came from — the provenance half every caller used to
/// be blind to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Source {
    /// The trained per-category MLP answered.
    Mlp,
    /// Degraded mode: no trained model for the category (or its forward
    /// failed), so the answer is the analytical theory roof.
    Roofline,
}

impl Source {
    pub fn name(&self) -> &'static str {
        match self {
            Source::Mlp => "mlp",
            Source::Roofline => "roofline",
        }
    }
}

/// Provenance of one answer: prediction source + whether the analytical
/// half came from the engine's memoizing cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Provenance {
    pub source: Source,
    pub cache_hit: bool,
}

/// Request options (see the [`PredictRequest`] builder methods).
#[derive(Debug, Clone, PartialEq)]
pub struct PredictOptions {
    pub flavor: Flavor,
    /// When `false`, a category without a usable MLP answers
    /// [`PredictError::PredictorUnavailable`] instead of the roofline.
    pub allow_degraded: bool,
    /// Attach the per-pipeline [`Breakdown`] to the response.
    pub with_breakdown: bool,
    /// Opaque trace tag echoed back in the response (request correlation
    /// for trace-level callers and the JSONL surface).
    pub tag: Option<String>,
    /// Admission deadline in milliseconds: how long the request may wait
    /// for queue space before answering
    /// [`PredictError::DeadlineExceeded`]. `None` waits as long as it
    /// takes (the stdio default — backpressure propagates to the peer).
    pub deadline_ms: Option<u64>,
}

impl Default for PredictOptions {
    fn default() -> Self {
        PredictOptions {
            flavor: Flavor::Mean,
            allow_degraded: true,
            with_breakdown: false,
            tag: None,
            deadline_ms: None,
        }
    }
}

/// A typed prediction request: one kernel launch on one GPU.
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub cfg: KernelConfig,
    pub gpu: GpuSpec,
    pub opts: PredictOptions,
}

impl PredictRequest {
    pub fn new(cfg: KernelConfig, gpu: GpuSpec) -> PredictRequest {
        PredictRequest { cfg, gpu, opts: PredictOptions::default() }
    }

    /// Ask the pinball-τ=0.8 ceiling model instead of the mean model.
    pub fn p80(mut self) -> Self {
        self.opts.flavor = Flavor::P80;
        self
    }

    /// Refuse degraded roofline answers: an untrained category errors with
    /// [`PredictError::PredictorUnavailable`].
    pub fn strict(mut self) -> Self {
        self.opts.allow_degraded = false;
        self
    }

    /// Attach the per-pipeline feature breakdown to the response.
    pub fn with_breakdown(mut self) -> Self {
        self.opts.with_breakdown = true;
        self
    }

    /// Attach an opaque correlation tag, echoed back in the response.
    pub fn tagged(mut self, tag: impl Into<String>) -> Self {
        self.opts.tag = Some(tag.into());
        self
    }

    /// Bound how long this request may wait for queue admission; an
    /// expired wait answers [`PredictError::DeadlineExceeded`].
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.opts.deadline_ms = Some(ms);
        self
    }

    /// Validate the launch geometry against the closed error taxonomy.
    pub fn validate(&self) -> Result<(), PredictError> {
        validate_config(&self.cfg)
    }
}

/// Per-pipe demand statistics (Table III pipes), attached on request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipeStat {
    pub total_ops: f64,
    pub max_sm_ops: f64,
    pub total_cycles: f64,
}

/// Per-pipeline feature breakdown of the analyzed launch (Table IV view) —
/// what `opts.with_breakdown` attaches to the response.
#[derive(Debug, Clone, PartialEq)]
pub struct Breakdown {
    pub tensor: PipeStat,
    pub fma: PipeStat,
    pub xu: PipeStat,
    /// Total MIO bytes moved (loads + stores).
    pub mio_bytes: f64,
    /// DRAM cycles of the memory subsystem model.
    pub dram_cycles: f64,
    /// The §IV synthesis roof the efficiency prediction scales.
    pub theory_sec: f64,
    /// The naive-roofline baseline answer for the same launch.
    pub naive_roofline_sec: f64,
}

impl Breakdown {
    fn from_analysis(a: &Analysis) -> Breakdown {
        let pipe = |p: &crate::features::PipeAgg| PipeStat {
            total_ops: p.total_ops,
            max_sm_ops: p.max_sm_ops,
            total_cycles: p.total_cycles,
        };
        Breakdown {
            tensor: pipe(&a.features.tensor),
            fma: pipe(&a.features.fma),
            xu: pipe(&a.features.xu),
            mio_bytes: a.features.mio.total_bytes,
            dram_cycles: a.features.mio.cycles_dram,
            theory_sec: a.features.theory_sec,
            naive_roofline_sec: a.features.naive_roofline_sec,
        }
    }
}

/// A typed prediction answer. Never a bare number: latency always travels
/// with its provenance and the flavor that produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictResponse {
    pub latency_sec: f64,
    pub provenance: Provenance,
    pub flavor: Flavor,
    pub kind: KernelKind,
    /// Echoed GPU name.
    pub gpu: String,
    pub breakdown: Option<Breakdown>,
    /// Echoed request tag.
    pub tag: Option<String>,
}

/// The closed error taxonomy of protocol v1. Every public prediction edge
/// answers with one of these — no stringly `anyhow` leaks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PredictError {
    /// The named GPU is not in the Table-VI spec database.
    UnknownGpu(String),
    /// The kernel description is malformed or outside the modeled space.
    UnsupportedKernel(String),
    /// `allow_degraded` was off and the category has no usable MLP.
    PredictorUnavailable(KernelKind),
    /// The bounded request queue is at capacity (backpressure signal).
    QueueFull,
    /// The request's admission deadline expired while the queue stayed
    /// saturated (the per-request `deadline_ms` backpressure edge).
    DeadlineExceeded,
    /// The service is shutting down (or already gone).
    Shutdown,
}

impl PredictError {
    /// Stable machine-readable code (the `error.code` of the wire surface).
    pub fn code(&self) -> &'static str {
        match self {
            PredictError::UnknownGpu(_) => "unknown_gpu",
            PredictError::UnsupportedKernel(_) => "unsupported_kernel",
            PredictError::PredictorUnavailable(_) => "predictor_unavailable",
            PredictError::QueueFull => "queue_full",
            PredictError::DeadlineExceeded => "deadline_exceeded",
            PredictError::Shutdown => "shutdown",
        }
    }
}

impl fmt::Display for PredictError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PredictError::UnknownGpu(name) => {
                write!(
                    f,
                    "unknown GPU {name:?} (see Table VI; closest: {})",
                    crate::hw::nearest_names(name, 3).join(", ")
                )
            }
            PredictError::UnsupportedKernel(why) => {
                write!(f, "unsupported kernel: {why}")
            }
            PredictError::PredictorUnavailable(kind) => {
                write!(f, "no trained predictor for category {:?} (degraded answers disabled)", kind)
            }
            PredictError::QueueFull => write!(f, "prediction queue at capacity"),
            PredictError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            PredictError::Shutdown => write!(f, "prediction service is shut down"),
        }
    }
}

impl std::error::Error for PredictError {}

/// Resolve a GPU by Table-VI name, with the typed error.
pub fn resolve_gpu(name: &str) -> Result<GpuSpec, PredictError> {
    gpu_by_name(name).ok_or_else(|| PredictError::UnknownGpu(name.to_string()))
}

/// Validate launch geometry: the request-path guard behind
/// [`PredictError::UnsupportedKernel`].
pub fn validate_config(cfg: &KernelConfig) -> Result<(), PredictError> {
    let bad = |why: String| Err(PredictError::UnsupportedKernel(why));
    match cfg {
        KernelConfig::Gemm { m, n, k, .. } | KernelConfig::ScaledMm { m, n, k } => {
            if *m == 0 || *n == 0 || *k == 0 {
                return bad(format!("gemm dims must be positive, got {m}x{n}x{k}"));
            }
        }
        KernelConfig::Attention { batch, nh, nkv, hd, .. } => {
            if batch.is_empty() {
                return bad("attention batch must be non-empty".into());
            }
            if *nkv == 0 || *nh < *nkv || *hd == 0 {
                return bad(format!("attention heads invalid: nh={nh} nkv={nkv} hd={hd}"));
            }
            for (q, kv) in batch {
                if *q == 0 || kv < q {
                    return bad(format!("attention request (q={q}, kv={kv}) needs kv >= q >= 1"));
                }
            }
        }
        KernelConfig::RmsNorm { seq, dim } | KernelConfig::SiluMul { seq, dim } => {
            if *seq == 0 || *dim == 0 {
                return bad(format!("shape must be positive, got {seq}x{dim}"));
            }
        }
        KernelConfig::FusedMoe { m, e, topk, h, n, expert_tokens, .. } => {
            if *m == 0 || *e == 0 || *topk == 0 || *h == 0 || *n == 0 {
                return bad(format!(
                    "fused_moe dims must be positive (m={m} e={e} topk={topk} h={h} n={n})"
                ));
            }
            if expert_tokens.len() != *e as usize {
                return bad(format!(
                    "fused_moe expert_tokens has {} entries for e={e} experts",
                    expert_tokens.len()
                ));
            }
            let routed: u64 = expert_tokens.iter().map(|&t| t as u64).sum();
            if routed != *m as u64 * *topk as u64 {
                return bad(format!(
                    "fused_moe routing is inconsistent: expert_tokens sums to {routed}, expected m*topk = {}",
                    *m as u64 * *topk as u64
                ));
            }
        }
    }
    Ok(())
}

/// The per-flavor trained model maps a service (or a local caller) owns.
/// Missing categories answer in degraded roofline mode (when allowed).
#[derive(Default)]
pub struct ModelBundle {
    pub mean: HashMap<KernelKind, Predictor>,
    pub p80: HashMap<KernelKind, Predictor>,
}

impl ModelBundle {
    /// Bundle with only mean-flavor models (the common case).
    pub fn with_mean(mean: HashMap<KernelKind, Predictor>) -> ModelBundle {
        ModelBundle { mean, p80: HashMap::new() }
    }

    fn map(&self, flavor: Flavor) -> &HashMap<KernelKind, Predictor> {
        match flavor {
            Flavor::Mean => &self.mean,
            Flavor::P80 => &self.p80,
        }
    }
}

/// Which feature view feeds the MLP: the SynPerf Table-IV vector or the
/// Neusight-baseline tile-level vector (used by the E2E comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureView {
    SynPerf,
    Neusight,
}

/// An untyped-options routed prediction: latency + provenance. The internal
/// currency of [`predict_batch_view`]; typed callers get [`PredictResponse`].
#[derive(Debug, Clone, Copy)]
pub struct RawPrediction {
    pub latency_sec: f64,
    pub kind: KernelKind,
    pub provenance: Provenance,
}

/// Aggregate outcome of one typed batch round (the coordinator metrics
/// consume the counters).
#[derive(Debug)]
pub struct BatchReport {
    /// Per-request results, in input order.
    pub results: Vec<Result<PredictResponse, PredictError>>,
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Distinct (flavor, category) MLP sub-batches this round routed into.
    pub kind_groups: usize,
}

/// Minimum requests per prospective worker before the routing pass fans
/// out (see [`route_view`]).
const ROUTE_PAR_GRAIN: usize = 32;

/// The shared routed-prediction core over borrowed request pairs.
///
/// Two fan-out stages, both over [`par::par_map`] (order preserving and
/// thread-count deterministic, so results are bit-identical to a serial
/// walk): the cached analyze pass — each worker probes its own cache shard
/// — and then one MLP forward per kernel category, one category per
/// worker. Categories without a usable model answer the theory roof with
/// [`Source::Roofline`] — per category, so one failing model never
/// degrades the whole batch. Infallible by construction.
///
/// The per-kind fan-out shares `&Predictor` across workers; under the
/// offline xla stub every executable is a host-side value (`Sync`), and a
/// real PJRT backend must keep its executables `Sync` to compile here.
fn route_view(
    models: &HashMap<KernelKind, Predictor>,
    view: FeatureView,
    pairs: &[(&KernelConfig, &GpuSpec)],
    threads: usize,
) -> Vec<RawPrediction> {
    // Small-batch guard: below ~ROUTE_PAR_GRAIN requests per prospective
    // worker the scoped-thread spawns cost more than the hot sharded-cache
    // probes they would parallelize, so a small service batch (the steady
    // 2–16-request regime under the 2 ms batching deadline) stays serial.
    // Purely a latency guard — results are identical either way.
    let threads = threads.min(pairs.len().div_ceil(ROUTE_PAR_GRAIN)).max(1);
    let engine = PredictionEngine::global();
    let analyses: Vec<(Arc<Analysis>, bool)> =
        par::par_map(pairs, threads, |_, &(cfg, gpu)| engine.analyze_hit(cfg, gpu));

    let mut by_kind: HashMap<KernelKind, Vec<usize>> = HashMap::new();
    for (i, (a, _)) in analyses.iter().enumerate() {
        by_kind.entry(a.kind).or_default().push(i);
    }
    let groups: Vec<(KernelKind, Vec<usize>)> = by_kind.into_iter().collect();

    let routed: Vec<Vec<(usize, RawPrediction)>> =
        par::par_map(&groups, threads, |_, (kind, idxs)| {
            let xs: Vec<[f32; FEATURE_DIM]> = idxs
                .iter()
                .map(|&i| match view {
                    FeatureView::SynPerf => analyses[i].0.x,
                    FeatureView::Neusight => analyses[i].0.x_alt,
                })
                .collect();
            let (effs, source) = match models.get(kind).map(|p| p.predict_eff(&xs)) {
                Some(Ok(effs)) => (effs, Source::Mlp),
                // untrained category, or a failing forward: the documented
                // degraded mode — efficiency 1.0 is exactly the theory roof
                Some(Err(_)) | None => (vec![1.0; xs.len()], Source::Roofline),
            };
            idxs.iter()
                .zip(effs)
                .map(|(&i, eff)| {
                    let a = &analyses[i].0;
                    let theory = match view {
                        FeatureView::SynPerf => a.features.theory_sec,
                        FeatureView::Neusight => a.alt_theory_sec,
                    };
                    let raw = RawPrediction {
                        latency_sec: theory / eff,
                        kind: *kind,
                        provenance: Provenance { source, cache_hit: analyses[i].1 },
                    };
                    (i, raw)
                })
                .collect()
        });

    let mut out: Vec<Option<RawPrediction>> = vec![None; pairs.len()];
    for part in routed {
        for (i, p) in part {
            out[i] = Some(p);
        }
    }
    out.into_iter().map(|p| p.expect("every request routed")).collect()
}

/// The one batched routing path: featurize every launch through the shared
/// engine cache, group by kernel category, run one MLP forward per
/// category, return latencies with provenance in input order (serial —
/// the mixed-GPU owned-pair surface the typed batch front door uses).
pub fn predict_batch_view(
    models: &HashMap<KernelKind, Predictor>,
    view: FeatureView,
    reqs: &[(KernelConfig, GpuSpec)],
) -> Vec<RawPrediction> {
    let pairs: Vec<(&KernelConfig, &GpuSpec)> = reqs.iter().map(|(c, g)| (c, g)).collect();
    route_view(models, view, &pairs, 1)
}

/// Borrowed single-GPU batched routing with parallel fan-out — the
/// two-pass evaluators' surface ([`crate::scenario::evaluate`],
/// `e2e::predict::eval_trace`). No `KernelConfig`/`GpuSpec` clones.
/// Latencies and provenance *sources* are bit-identical to
/// [`predict_batch_view`] at any `threads`; the `cache_hit` flag of
/// duplicate not-yet-cached keys can differ when their probes race
/// (both may miss). The evaluators are immune: their pass 1 warms every
/// key before this routing pass runs.
pub fn predict_batch_view_on(
    models: &HashMap<KernelKind, Predictor>,
    view: FeatureView,
    gpu: &GpuSpec,
    cfgs: &[&KernelConfig],
    threads: usize,
) -> Vec<RawPrediction> {
    let pairs: Vec<(&KernelConfig, &GpuSpec)> = cfgs.iter().map(|&c| (c, gpu)).collect();
    route_view(models, view, &pairs, threads)
}

/// Typed batch prediction: validate, route per flavor through the shared
/// routing core, and assemble provenance-carrying responses. Results are
/// in input order; a bad request yields its typed error without affecting
/// the rest of the batch. Serial; the coordinator's batch loop calls
/// [`predict_batch_threads`] to fan the routing pass out.
pub fn predict_batch(bundle: &ModelBundle, reqs: &[PredictRequest]) -> BatchReport {
    predict_batch_threads(bundle, reqs, 1)
}

/// [`predict_batch`] with the routing pass (cached analyze + per-kind
/// forwards) fanned out over `threads` workers — batches below ~32
/// requests per worker run serially anyway (thread spawns would cost more
/// than the hot-cache probes), so a steady small-batch service pays
/// nothing for a large `threads`. Latencies and provenance sources are
/// identical at any thread count; only the `cache_hit` flag of
/// *duplicate* keys racing within one batch can differ (both may miss).
pub fn predict_batch_threads(
    bundle: &ModelBundle,
    reqs: &[PredictRequest],
    threads: usize,
) -> BatchReport {
    let engine = PredictionEngine::global();
    let mut results: Vec<Option<Result<PredictResponse, PredictError>>> =
        (0..reqs.len()).map(|_| None).collect();
    let mut cache_hits = 0usize;
    let mut cache_misses = 0usize;
    let mut groups: HashSet<(Flavor, KernelKind)> = HashSet::new();

    for flavor in [Flavor::Mean, Flavor::P80] {
        let mut idxs = Vec::new();
        let mut pairs: Vec<(&KernelConfig, &GpuSpec)> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            if r.opts.flavor != flavor {
                continue;
            }
            match r.validate() {
                Ok(()) => {
                    idxs.push(i);
                    pairs.push((&r.cfg, &r.gpu));
                }
                Err(e) => results[i] = Some(Err(e)),
            }
        }
        if idxs.is_empty() {
            continue;
        }
        let raw = route_view(bundle.map(flavor), FeatureView::SynPerf, &pairs, threads);
        for (&i, p) in idxs.iter().zip(&raw) {
            let req = &reqs[i];
            if p.provenance.cache_hit {
                cache_hits += 1;
            } else {
                cache_misses += 1;
            }
            groups.insert((flavor, p.kind));
            if p.provenance.source == Source::Roofline && !req.opts.allow_degraded {
                results[i] = Some(Err(PredictError::PredictorUnavailable(p.kind)));
                continue;
            }
            // the analysis is cached by the routing pass above, so the
            // breakdown attachment is a cheap cache hit
            let breakdown = if req.opts.with_breakdown {
                Some(Breakdown::from_analysis(&engine.analyze(&req.cfg, &req.gpu)))
            } else {
                None
            };
            results[i] = Some(Ok(PredictResponse {
                latency_sec: p.latency_sec,
                provenance: p.provenance,
                flavor,
                kind: p.kind,
                gpu: req.gpu.name.to_string(),
                breakdown,
                tag: req.opts.tag.clone(),
            }));
        }
    }
    BatchReport {
        results: results.into_iter().map(|r| r.expect("every request answered")).collect(),
        cache_hits,
        cache_misses,
        kind_groups: groups.len(),
    }
}

/// Single typed prediction (see [`predict_batch`]).
pub fn predict_one(
    bundle: &ModelBundle,
    req: &PredictRequest,
) -> Result<PredictResponse, PredictError> {
    predict_batch(bundle, std::slice::from_ref(req))
        .results
        .pop()
        .expect("one request, one result")
}

/// Validated analyze + oracle-profile into a training [`Sample`] — the
/// dataset builder's entry into the shared request path.
pub fn profile_sample(cfg: &KernelConfig, gpu: &GpuSpec, seed: u64) -> Result<Sample, PredictError> {
    validate_config(cfg)?;
    Ok(PredictionEngine::global().make_sample(cfg, gpu, seed))
}

/// Validated dataset build over the engine's parallel fan-out (the sampled
/// configs are valid by construction; validation guards foreign callers).
pub fn build_dataset(
    kind: KernelKind,
    gpus: &[GpuSpec],
    n_configs: usize,
    seed: u64,
    threads: usize,
) -> Vec<Sample> {
    PredictionEngine::global().build_dataset(kind, gpus, n_configs, seed, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::DType;

    fn gemm(m: u32, n: u32, k: u32) -> KernelConfig {
        KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 }
    }

    #[test]
    fn builder_sets_options() {
        let gpu = resolve_gpu("A100").unwrap();
        let r = PredictRequest::new(gemm(64, 64, 64), gpu).p80().strict().with_breakdown().tagged("t");
        assert_eq!(r.opts.flavor, Flavor::P80);
        assert!(!r.opts.allow_degraded);
        assert!(r.opts.with_breakdown);
        assert_eq!(r.opts.tag.as_deref(), Some("t"));
    }

    #[test]
    fn unknown_gpu_is_typed() {
        let e = resolve_gpu("TPUv5").unwrap_err();
        assert_eq!(e, PredictError::UnknownGpu("TPUv5".into()));
        assert_eq!(e.code(), "unknown_gpu");
    }

    #[test]
    fn validation_catches_bad_geometry() {
        assert!(validate_config(&gemm(0, 64, 64)).is_err());
        assert!(validate_config(&KernelConfig::Attention {
            batch: vec![],
            nh: 8,
            nkv: 2,
            hd: 128,
            causal: true,
            fa3: false,
        })
        .is_err());
        assert!(validate_config(&KernelConfig::Attention {
            batch: vec![(128, 64)], // kv < q
            nh: 8,
            nkv: 2,
            hd: 128,
            causal: true,
            fa3: false,
        })
        .is_err());
        assert!(validate_config(&KernelConfig::RmsNorm { seq: 4, dim: 0 }).is_err());
        assert!(validate_config(&gemm(64, 64, 64)).is_ok());
        // fused_moe: zero tokens and inconsistent routing are both refused
        let moe = |m: u32, expert_tokens: Vec<u32>| KernelConfig::FusedMoe {
            m,
            e: 2,
            topk: 2,
            h: 64,
            n: 32,
            expert_tokens,
            cfg: crate::kernels::MoeConfig {
                block_m: 16,
                block_n: 64,
                block_k: 64,
                num_stages: 4,
                num_warps: 8,
            },
        };
        assert!(validate_config(&moe(0, vec![0, 0])).is_err());
        assert!(validate_config(&moe(8, vec![4, 4])).is_err(), "sums to 8, expected 16");
        assert!(validate_config(&moe(8, vec![10, 6])).is_ok());
    }

    #[test]
    fn degraded_batch_is_roofline_with_provenance() {
        let gpu = resolve_gpu("A100").unwrap();
        // unique shape: independent of other tests sharing the global engine
        let reqs = vec![
            PredictRequest::new(gemm(1733, 911, 641), gpu.clone()),
            PredictRequest::new(KernelConfig::RmsNorm { seq: 1733, dim: 911 }, gpu.clone()),
            PredictRequest::new(gemm(1733, 911, 641), gpu.clone()),
        ];
        let report = predict_batch(&ModelBundle::default(), &reqs);
        assert_eq!(report.results.len(), 3);
        assert_eq!(report.kind_groups, 2);
        assert_eq!(report.cache_hits + report.cache_misses, 3);
        let first = report.results[0].as_ref().unwrap();
        let third = report.results[2].as_ref().unwrap();
        assert_eq!(first.provenance.source, Source::Roofline);
        assert_eq!(first.latency_sec.to_bits(), third.latency_sec.to_bits());
        let direct = PredictionEngine::global().analyze(&reqs[0].cfg, &gpu);
        assert_eq!(first.latency_sec.to_bits(), direct.theory_sec().to_bits());
    }

    #[test]
    fn strict_mode_refuses_degraded_answers() {
        let gpu = resolve_gpu("H800").unwrap();
        let req = PredictRequest::new(gemm(257, 769, 513), gpu).strict();
        let err = predict_one(&ModelBundle::default(), &req).unwrap_err();
        assert_eq!(err, PredictError::PredictorUnavailable(KernelKind::Gemm));
        assert_eq!(err.code(), "predictor_unavailable");
    }

    #[test]
    fn breakdown_attaches_pipeline_features() {
        let gpu = resolve_gpu("A100").unwrap();
        let req = PredictRequest::new(gemm(2048, 2048, 1024), gpu.clone()).with_breakdown();
        let resp = predict_one(&ModelBundle::default(), &req).unwrap();
        let b = resp.breakdown.expect("breakdown requested");
        assert!(b.tensor.total_ops > 0.0);
        assert!(b.mio_bytes > 0.0);
        assert!(b.theory_sec > 0.0 && b.naive_roofline_sec > 0.0);
        assert_eq!(resp.latency_sec.to_bits(), b.theory_sec.to_bits(), "degraded answer is the roof");
        // a mixed-validity batch answers element-wise
        let bad = PredictRequest::new(gemm(0, 1, 1), gpu);
        let report = predict_batch(&ModelBundle::default(), &[req, bad]);
        assert!(report.results[0].is_ok());
        assert!(matches!(report.results[1], Err(PredictError::UnsupportedKernel(_))));
    }

    #[test]
    fn neusight_view_uses_alt_theory() {
        let gpu = resolve_gpu("L40").unwrap();
        let pairs = vec![(gemm(1021, 517, 389), gpu.clone())];
        let syn = predict_batch_view(&HashMap::new(), FeatureView::SynPerf, &pairs);
        let neu = predict_batch_view(&HashMap::new(), FeatureView::Neusight, &pairs);
        let a = PredictionEngine::global().analyze(&pairs[0].0, &gpu);
        assert_eq!(syn[0].latency_sec.to_bits(), a.features.theory_sec.to_bits());
        assert_eq!(neu[0].latency_sec.to_bits(), a.alt_theory_sec.to_bits());
    }

    #[test]
    fn profile_sample_validates_first() {
        let gpu = resolve_gpu("A40").unwrap();
        assert!(profile_sample(&gemm(0, 1, 1), &gpu, 1).is_err());
        let s = profile_sample(&gemm(512, 512, 256), &gpu, 1).unwrap();
        assert!(s.latency_sec > 0.0);
    }
}
