//! The JSONL stdio surface of `synperf serve --stdio`: one request per line
//! in, one response per line out, **in input order**. A reader thread
//! parses and submits lines into the coordinator ([`Client::submit`] blocks
//! when the bounded queue is full, so backpressure propagates to the peer)
//! while the caller's thread writes responses as they resolve — an
//! interactive request/await peer gets each answer promptly, and a
//! pipelining peer fills real batches. The in-flight window is bounded by
//! `max_inflight` (a `sync_channel`), bounding memory.
//!
//! The surface speaks three verbs, dispatched per line: **predict** (the
//! default — a kernel-latency request into the coordinator queue),
//! **simulate** (`"op":"simulate"` with a `"scenario"` object for the v1
//! single-node path, or a `"cluster"` object for the v2 discrete-event
//! cluster simulation — both through the [`Simulator`]), **sweep**
//! (`"op":"sweep"` — a whole hardware-search grid answered as one line
//! embedding every row plus the Pareto frontier) and **tune**
//! (`"op":"tune"` — a §VII ceiling-guided autotune run answered as one
//! line embedding every row plus the summary). Each line is JSON-decoded
//! exactly once; the decoded object picks the verb and feeds the winning
//! codec. Simulate, sweep and tune lines are evaluated on the writer
//! thread when their turn comes, so output order still matches input order
//! exactly — the in-order contract means later predict answers
//! intentionally wait behind an earlier simulate line (head-of-line),
//! exactly as they wait behind any earlier slow response. The `Simulator`
//! is built lazily by the supplied factory on the first simulate line, so
//! predict-only peers never pay its model-set startup cost; sweep lines
//! build one simulator per sweep worker through the same factory, and tune
//! lines probe the P80-ceiling artifact per worker ([`crate::autotune::Ceiling::auto`]).

use super::serve::{self, LineReader, Parsed, ReadLine};
use super::wire;
use super::{PredictError, PredictResponse};
use crate::autotune::{self, TuneError, TuneSpec};
use crate::coordinator::{Client, Pending};
use crate::scenario::wire::SimulateRequest;
use crate::scenario::{self, ScenarioError, Simulator};
use crate::sweep::{self, SweepError, SweepRequest};
use std::io::{BufRead, Write};
use std::sync::mpsc::{sync_channel, TryRecvError};

/// Counters the CLI prints on exit (to stderr — stdout carries responses).
#[derive(Debug, Default, Clone, Copy)]
pub struct StdioStats {
    pub served: u64,
    pub errors: u64,
    /// How many of `served` were simulate-verb lines.
    pub simulated: u64,
    /// How many of `served` were sweep-verb lines (each answering a whole
    /// grid in one response).
    pub swept: u64,
    /// How many of `served` were tune-verb lines (each answering a whole
    /// autotune run in one response).
    pub tuned: u64,
    /// How many of `served` were stats-verb lines.
    pub stats_lines: u64,
    /// Lines refused for exceeding [`serve::MAX_LINE_BYTES`] (each counted
    /// in `errors` too; the connection stays up).
    pub oversized: u64,
}

/// One in-flight line: a queued prediction, an already-decided
/// (parse/submit) error, an oversized-line refusal, or a simulate / sweep
/// / stats verb awaiting its in-order turn — delivered in arrival order so
/// output order matches input order exactly.
enum Slot {
    Queued(Option<String>, Pending),
    Ready(Option<String>, Result<PredictResponse, PredictError>),
    Oversized(usize),
    Simulate(Option<String>, Result<SimulateRequest, ScenarioError>),
    Sweep(Option<String>, Result<SweepRequest, SweepError>),
    Tune(Option<String>, Result<TuneSpec, TuneError>),
    Stats(Option<String>),
}

/// Run the serve loop until the reader is exhausted. Every input line
/// produces exactly one output line (blank lines are skipped). The output
/// is flushed whenever no further response is immediately ready, so an
/// interactive peer never waits on a stuck buffer or a half-full window.
pub fn serve_lines<R, W, F>(
    client: &Client,
    simulator: F,
    reader: R,
    writer: &mut W,
    max_inflight: usize,
    threads: usize,
) -> std::io::Result<StdioStats>
where
    R: BufRead + Send,
    W: Write,
    F: Fn() -> Simulator + Sync,
{
    let mut stats = StdioStats::default();
    let (slot_tx, slot_rx) = sync_channel::<Slot>(max_inflight.max(1));
    std::thread::scope(|scope| -> std::io::Result<()> {
        let reader_thread = scope.spawn(move || -> std::io::Result<()> {
            // capped line reads: one oversized line answers a typed error
            // instead of exhausting memory, and the stream stays in sync
            let mut lines = LineReader::new(reader, serve::MAX_LINE_BYTES);
            loop {
                let slot = match lines.read_line()? {
                    ReadLine::Eof => break,
                    ReadLine::Idle => continue, // stdio readers block; defensive
                    ReadLine::Oversized(n) => Slot::Oversized(n),
                    ReadLine::Line(line) => {
                        if line.trim().is_empty() {
                            continue;
                        }
                        // one JSON decode per line; the object picks the verb
                        match serve::classify(&line) {
                            Parsed::Malformed(why) => {
                                Slot::Ready(None, Err(PredictError::UnsupportedKernel(why)))
                            }
                            Parsed::Stats(id) => Slot::Stats(id),
                            Parsed::Sweep(id, spec) => Slot::Sweep(id, spec),
                            Parsed::Tune(id, spec) => Slot::Tune(id, spec),
                            Parsed::Simulate(id, req) => Slot::Simulate(id, req),
                            Parsed::Predict(id, Ok(req)) => {
                                match serve::submit_predict(client, req) {
                                    Ok(pending) => Slot::Queued(id, pending),
                                    Err(e) => Slot::Ready(id, Err(e)),
                                }
                            }
                            Parsed::Predict(id, Err(e)) => Slot::Ready(id, Err(e)),
                        }
                    }
                };
                // the writer side hung up (output error): stop reading
                if slot_tx.send(slot).is_err() {
                    break;
                }
            }
            Ok(())
        });

        // drain_slots takes the receiver by value: on a writer I/O error
        // the receiver is dropped before we join, which unblocks the
        // reader thread's send — the scope join cannot deadlock
        let drain_res = drain_slots(slot_rx, client, &simulator, threads, writer, &mut stats);
        let read_res = reader_thread.join().expect("stdio reader thread");
        drain_res?;
        read_res
    })?;
    Ok(stats)
}

/// Writer side, on the caller's thread: answer slots in order; flush
/// before blocking so a waiting peer sees everything answered so far.
/// Simulate slots run here — the `Simulator` never crosses a thread, and
/// is only built (once) when the first simulate line arrives. Sweep slots
/// fan out through [`sweep::run_request`], which builds one simulator per
/// worker from the same factory; `threads` bounds that fan-out.
fn drain_slots<W: Write, F: Fn() -> Simulator + Sync>(
    slot_rx: std::sync::mpsc::Receiver<Slot>,
    client: &Client,
    simulator: &F,
    threads: usize,
    writer: &mut W,
    stats: &mut StdioStats,
) -> std::io::Result<()> {
    let mut sim: Option<Simulator> = None;
    loop {
        let slot = match slot_rx.try_recv() {
            Ok(slot) => slot,
            Err(TryRecvError::Empty) => {
                writer.flush()?;
                match slot_rx.recv() {
                    Ok(slot) => slot,
                    Err(_) => break, // reader done, everything drained
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        let (id, res) = match slot {
            Slot::Queued(id, pending) => (id, pending.wait()),
            Slot::Ready(id, res) => (id, res),
            Slot::Oversized(n) => {
                stats.oversized += 1;
                (None, Err(serve::oversized_error(n)))
            }
            Slot::Stats(id) => {
                stats.served += 1;
                stats.stats_lines += 1;
                // counted before assembly, so the report includes itself;
                // the stdio surface has exactly one (implicit) peer
                let report = serve::build_stats(
                    client,
                    stats.served,
                    stats.errors,
                    stats.simulated,
                    stats.swept,
                    stats.tuned,
                    wire::ClientStats {
                        connected: 1,
                        total: 1,
                        oversized_lines: stats.oversized,
                        ..wire::ClientStats::default()
                    },
                );
                writeln!(writer, "{}", wire::encode_stats(id.as_deref(), &report))?;
                continue;
            }
            Slot::Sweep(id, req) => {
                stats.served += 1;
                stats.swept += 1;
                // rows stream internally but the wire stays
                // one-line-per-request: the response embeds every row;
                // shard + journal envelope fields are honored (a journal
                // is create-or-resume on this surface)
                let res = req.and_then(|req| sweep::run_request(&req, simulator, threads));
                if res.is_err() {
                    stats.errors += 1;
                }
                writeln!(writer, "{}", sweep::wire::encode_sweep_response(id.as_deref(), &res))?;
                continue;
            }
            Slot::Tune(id, spec) => {
                stats.served += 1;
                stats.tuned += 1;
                // like sweep: rows stream internally but the wire stays
                // one-line-per-request — the response embeds rows + summary
                let res = spec
                    .and_then(|spec| autotune::run_tune(&spec, autotune::Ceiling::auto, threads, |_| {}));
                if res.is_err() {
                    stats.errors += 1;
                }
                writeln!(writer, "{}", autotune::wire::encode_tune_response(id.as_deref(), &res))?;
                continue;
            }
            Slot::Simulate(id, req) => {
                let sim = sim.get_or_insert_with(simulator);
                stats.served += 1;
                stats.simulated += 1;
                // parse errors answer in the shape the request asked for;
                // an unparseable line defaults to the v1 report envelope
                let line = match req {
                    Ok(SimulateRequest::Scenario(spec)) => {
                        let res = sim.simulate(&spec);
                        if res.is_err() {
                            stats.errors += 1;
                        }
                        scenario::wire::encode_report(id.as_deref(), &res)
                    }
                    Ok(SimulateRequest::Cluster(spec)) => {
                        let res = sim.simulate_cluster(&spec);
                        if res.is_err() {
                            stats.errors += 1;
                        }
                        scenario::wire::encode_cluster_report(id.as_deref(), &res)
                    }
                    Err(e) => {
                        stats.errors += 1;
                        scenario::wire::encode_report(id.as_deref(), &Err(e))
                    }
                };
                writeln!(writer, "{line}")?;
                continue;
            }
        };
        stats.served += 1;
        if res.is_err() {
            stats.errors += 1;
        }
        writeln!(writer, "{}", wire::encode_response(id.as_deref(), &res))?;
    }
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ModelBundle;
    use crate::coordinator::{PredictionService, ServiceConfig};
    use std::io::Read;
    use std::sync::mpsc::{channel, Receiver};
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    #[test]
    fn one_line_in_one_line_out_in_order() {
        let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
        let input = concat!(
            r#"{"id":"a","gpu":"A100","kernel":{"type":"gemm","m":512,"n":512,"k":512}}"#,
            "\n",
            "\n", // blank lines are skipped
            r#"{"id":"b","gpu":"B300","kernel":{"type":"gemm","m":1,"n":1,"k":1}}"#,
            "\n",
            "this is not json\n",
            r#"{"id":"d","gpu":"H800","kernel":{"type":"rmsnorm","seq":256,"dim":4096},"tag":"z"}"#,
            "\n",
        );
        let mut out = Vec::new();
        let stats =
            serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2)
                .unwrap();
        assert_eq!(stats.served, 4);
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.simulated, 0);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains(r#""id":"a""#) && lines[0].contains(r#""ok":true"#));
        // degraded service: provenance distinguishes the roofline fallback
        assert!(lines[0].contains(r#""source":"roofline""#));
        assert!(lines[1].contains(r#""id":"b""#) && lines[1].contains(r#""code":"unknown_gpu""#));
        assert!(lines[2].contains(r#""ok":false"#));
        assert!(lines[3].contains(r#""id":"d""#) && lines[3].contains(r#""tag":"z""#));
        svc.shutdown();
    }

    #[test]
    fn simulate_and_predict_verbs_interleave_in_order() {
        let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
        let input = concat!(
            r#"{"id":"s1","op":"simulate","scenario":{"model":"llama3.1-8b","gpu":"A100","workload":{"requests":[[64,8],[96,4]]},"seed":3}}"#,
            "\n",
            r#"{"id":"p1","gpu":"A100","kernel":{"type":"rmsnorm","seq":128,"dim":2048}}"#,
            "\n",
            r#"{"id":"s2","op":"simulate","scenario":{"model":"GPT-5","gpu":"A100"}}"#,
            "\n",
        );
        let mut out = Vec::new();
        let stats =
            serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2)
                .unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.simulated, 2);
        assert_eq!(stats.errors, 1);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains(r#""id":"s1""#) && lines[0].contains(r#""report":{"#));
        assert!(lines[0].contains(r#""ttft_sec""#) && lines[0].contains(r#""tpot_sec""#));
        assert!(lines[1].contains(r#""id":"p1""#) && lines[1].contains(r#""ok":true"#));
        assert!(lines[2].contains(r#""code":"unknown_model""#));
        // the report line parses back typed
        let (id, rep) = scenario::wire::parse_report(lines[0]).unwrap();
        assert_eq!(id.as_deref(), Some("s1"));
        let rep = rep.unwrap();
        assert_eq!(rep.phases.len(), 2);
        assert!(rep.totals.degraded_kernels > 0, "degraded provenance travels the wire");
        svc.shutdown();
    }

    #[test]
    fn cluster_lines_ride_the_simulate_verb() {
        let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
        let input = concat!(
            r#"{"id":"c1","op":"simulate","cluster":{"model":"Llama3.1-8B","gpu":"A100","replicas":2,"arrivals":{"trace":[[0.0,128,8],[0.001,96,4]]},"kv_capacity_tokens":4096}}"#,
            "\n",
            r#"{"id":"p1","gpu":"A100","kernel":{"type":"rmsnorm","seq":128,"dim":2048}}"#,
            "\n",
            r#"{"id":"c2","op":"simulate","cluster":{"model":"Llama3.1-8B","gpu":"A100","replicas":0}}"#,
            "\n",
        );
        let mut out = Vec::new();
        let stats =
            serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2)
                .unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.simulated, 2);
        assert_eq!(stats.errors, 1);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains(r#""id":"c1""#) && lines[0].contains(r#""cluster":true"#));
        assert!(lines[1].contains(r#""id":"p1""#) && lines[1].contains(r#""ok":true"#));
        assert!(lines[2].contains(r#""code":"invalid_cluster""#));
        let (id, rep) = scenario::wire::parse_cluster_report(lines[0]).unwrap();
        assert_eq!(id.as_deref(), Some("c1"));
        let rep = rep.unwrap();
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.replicas.len(), 2);
        assert!(rep.ttft.p50_sec > 0.0);
        svc.shutdown();
    }

    #[test]
    fn sweep_lines_answer_in_one_line_between_other_verbs() {
        let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
        let input = concat!(
            r#"{"id":"p1","gpu":"A100","kernel":{"type":"rmsnorm","seq":128,"dim":2048}}"#,
            "\n",
            r#"{"id":"w1","op":"sweep","sweep":{"gpus":["A100","H800"],"tp":[1,3],"workloads":[{"name":"tiny","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}}]}}"#,
            "\n",
            r#"{"id":"w2","op":"sweep","sweep":{"gpus":["B300"],"workloads":[{"scenario":{"model":"llama3.1-8b"}}]}}"#,
            "\n",
        );
        let mut out = Vec::new();
        let stats =
            serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2)
                .unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.swept, 2);
        assert_eq!(stats.simulated, 0);
        // only the spec-level failure counts as an error: infeasible
        // points are typed rows inside an ok response
        assert_eq!(stats.errors, 1);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains(r#""id":"p1""#) && lines[0].contains(r#""ok":true"#));
        assert!(lines[1].contains(r#""id":"w1""#) && lines[1].contains(r#""ok":true"#));
        // 2 GPUs x tp {1,3}: four rows, the tp=3 ones infeasible for a
        // 32-head model, plus a non-empty frontier — all in one line
        assert!(lines[1].contains(r#""index":3"#), "{}", lines[1]);
        assert!(lines[1].contains(r#""code":"invalid_parallelism""#));
        assert!(lines[1].contains(r#""frontier":[{"rank":1,"#));
        assert!(lines[2].contains(r#""id":"w2""#) && lines[2].contains(r#""ok":false"#));
        assert!(lines[2].contains(r#""code":"unknown_gpu""#));
        assert!(lines[2].contains("closest: A100, H800, H100"));
        svc.shutdown();
    }

    #[test]
    fn multi_megabyte_line_answers_typed_error_and_stream_survives() {
        let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
        let mut input = Vec::new();
        input.extend_from_slice(
            br#"{"id":"a","gpu":"A100","kernel":{"type":"rmsnorm","seq":96,"dim":1024}}"#,
        );
        input.push(b'\n');
        // 3 MiB of garbage on one line: must answer a typed error without
        // buffering the whole thing as a String, and without desyncing
        input.resize(input.len() + (3 << 20), b'z');
        input.push(b'\n');
        input.extend_from_slice(
            br#"{"id":"b","gpu":"A100","kernel":{"type":"rmsnorm","seq":97,"dim":1024}}"#,
        );
        input.push(b'\n');
        let mut out = Vec::new();
        let stats =
            serve_lines(&svc.client(), Simulator::degraded, &input[..], &mut out, 8, 2).unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.oversized, 1);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains(r#""id":"a""#) && lines[0].contains(r#""ok":true"#));
        assert!(
            lines[1].contains(r#""code":"unsupported_kernel""#)
                && lines[1].contains("oversized line"),
            "{}",
            lines[1]
        );
        assert!(
            lines[2].contains(r#""id":"b""#) && lines[2].contains(r#""ok":true"#),
            "stream must stay in sync after the oversized line"
        );
        svc.shutdown();
    }

    #[test]
    fn stats_verb_reports_surface_counters() {
        let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
        let input = concat!(
            r#"{"id":"p1","gpu":"A100","kernel":{"type":"rmsnorm","seq":4441,"dim":1024}}"#,
            "\n",
            r#"{"id":"st","op":"stats"}"#,
            "\n",
        );
        let mut out = Vec::new();
        let stats =
            serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2)
                .unwrap();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.stats_lines, 1);
        assert_eq!(stats.errors, 0);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let (id, report) = wire::parse_stats(lines[1]).unwrap();
        assert_eq!(id.as_deref(), Some("st"));
        assert_eq!(report.served, 2, "the stats line counts itself");
        assert_eq!(report.clients.connected, 1);
        assert_eq!(report.clients.total, 1);
        // the predict answer resolved before the stats slot's turn, and
        // metrics record before answering — so it is already visible
        assert_eq!(report.requests, 1);
        svc.shutdown();
    }

    /// Blocking reader fed line-by-line over a channel — emulates an
    /// interactive peer that keeps its stdin open between requests.
    struct ChanReader {
        rx: Receiver<String>,
        buf: Vec<u8>,
        pos: usize,
    }

    impl Read for ChanReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.buf.len() {
                match self.rx.recv() {
                    Ok(s) => {
                        self.buf = s.into_bytes();
                        self.pos = 0;
                    }
                    Err(_) => return Ok(0), // sender dropped: EOF
                }
            }
            let n = (self.buf.len() - self.pos).min(out.len());
            out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[derive(Clone)]
    struct SharedWriter(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn interactive_peer_gets_each_answer_without_eof() {
        // a request/await peer: the response for line N must arrive while
        // stdin stays open, with no further input and a far-from-full window
        let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
        let client = svc.client();
        let (line_tx, line_rx) = channel::<String>();
        let out = Arc::new(Mutex::new(Vec::new()));
        let mut writer = SharedWriter(out.clone());
        let server = std::thread::spawn(move || {
            let reader =
                std::io::BufReader::new(ChanReader { rx: line_rx, buf: Vec::new(), pos: 0 });
            serve_lines(&client, Simulator::degraded, reader, &mut writer, 256, 2)
        });
        for i in 0..3usize {
            line_tx
                .send(format!(
                    "{{\"id\":\"i{i}\",\"gpu\":\"A100\",\"kernel\":{{\"type\":\"rmsnorm\",\"seq\":{},\"dim\":1024}}}}\n",
                    64 + i
                ))
                .unwrap();
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                let answered =
                    String::from_utf8(out.lock().unwrap().clone()).unwrap().lines().count();
                if answered > i {
                    break;
                }
                assert!(Instant::now() < deadline, "response {i} withheld until EOF");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        drop(line_tx); // EOF
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.errors, 0);
        svc.shutdown();
    }
}
