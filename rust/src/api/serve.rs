//! Shared line-serving core of the two JSONL wire surfaces
//! ([`super::stdio`] and [`super::tcp`]): a **capped** line reader (the
//! unbounded `BufRead::lines` hazard is gone — a hostile peer cannot make
//! one line exhaust memory), per-line verb classification (one JSON decode
//! per line picks predict / simulate / sweep / tune / stats), deadline-aware
//! queue admission, and the assembly of the `stats` verb's report. Both
//! surfaces answer through the same codecs in [`super::wire`],
//! [`crate::scenario::wire`] and [`crate::sweep::wire`], which is what
//! makes their response bytes identical for the same request stream.

use super::wire;
use super::{PredictError, PredictRequest};
use crate::autotune::{self, TuneError, TuneSpec};
use crate::coordinator::{Client, Pending};
use crate::scenario::wire::SimulateRequest;
use crate::scenario::{self, ScenarioError};
use crate::sweep::{self, SweepError, SweepRequest};
use crate::util::json::parse as parse_json;
use std::io::{ErrorKind, Read};
use std::time::Duration;

/// Hard cap on one request line (1 MiB). A line that exceeds it is
/// discarded up to its newline and answered with a typed error — the
/// stream stays in sync and the connection stays up.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// One read attempt's outcome.
pub enum ReadLine {
    /// A complete line (without its `\n`; a trailing `\r` is stripped,
    /// matching `BufRead::lines`). Invalid UTF-8 is replaced rather than
    /// erroring, so a hostile peer cannot kill the stream with raw bytes —
    /// the replacement characters surface as a malformed-JSON error line.
    Line(String),
    /// The line exceeded the cap; `usize` is how many bytes were
    /// discarded. The reader has already skipped to the next newline.
    Oversized(usize),
    /// The underlying read timed out (`WouldBlock`/`TimedOut`) with the
    /// line still incomplete — the socket-timeout tick of the TCP reader.
    Idle,
    /// End of stream. An unterminated final line is returned as
    /// [`ReadLine::Line`] first (again matching `BufRead::lines`).
    Eof,
}

/// Capped line reader over any [`Read`]. Owns an 8 KiB scratch buffer and
/// the partial-line accumulator; never holds more than `max_line` bytes of
/// line plus one scratch chunk, whatever the peer sends.
pub struct LineReader<R> {
    inner: R,
    chunk: Vec<u8>,
    filled: usize,
    pos: usize,
    line: Vec<u8>,
    max_line: usize,
    /// When > 0: an oversized line is being discarded; counts the bytes
    /// dropped so far so the typed error can report the size.
    skipping: usize,
}

impl<R: Read> LineReader<R> {
    pub fn new(inner: R, max_line: usize) -> LineReader<R> {
        LineReader {
            inner,
            chunk: vec![0u8; 8192],
            filled: 0,
            pos: 0,
            line: Vec::new(),
            max_line: max_line.max(1),
            skipping: 0,
        }
    }

    /// Bytes of the current (incomplete) line accumulated or skipped so
    /// far — the TCP reader's progress gauge for idle-reap decisions: a
    /// trickling peer grows this, a silent one doesn't.
    pub fn pending(&self) -> usize {
        self.line.len() + self.skipping
    }

    fn take_line(&mut self) -> String {
        if self.line.last() == Some(&b'\r') {
            self.line.pop();
        }
        let s = String::from_utf8_lossy(&self.line).into_owned();
        self.line.clear();
        s
    }

    /// Read until a newline, the cap, a timeout, or EOF.
    pub fn read_line(&mut self) -> std::io::Result<ReadLine> {
        loop {
            // scan whatever is buffered for a newline
            while self.pos < self.filled {
                let nl = self.chunk[self.pos..self.filled].iter().position(|&b| b == b'\n');
                match nl {
                    Some(rel) => {
                        let upto = self.pos + rel;
                        if self.skipping > 0 {
                            let n = self.skipping + (upto - self.pos);
                            self.skipping = 0;
                            self.pos = upto + 1;
                            return Ok(ReadLine::Oversized(n));
                        }
                        self.line.extend_from_slice(&self.chunk[self.pos..upto]);
                        self.pos = upto + 1;
                        if self.line.len() > self.max_line {
                            let n = self.line.len();
                            self.line.clear();
                            return Ok(ReadLine::Oversized(n));
                        }
                        return Ok(ReadLine::Line(self.take_line()));
                    }
                    None => {
                        if self.skipping > 0 {
                            self.skipping += self.filled - self.pos;
                        } else {
                            self.line.extend_from_slice(&self.chunk[self.pos..self.filled]);
                            if self.line.len() > self.max_line {
                                // flip to discard mode: stop buffering,
                                // keep counting until the newline
                                self.skipping = self.line.len();
                                self.line.clear();
                            }
                        }
                        self.pos = self.filled;
                    }
                }
            }
            self.pos = 0;
            self.filled = 0;
            match self.inner.read(&mut self.chunk) {
                Ok(0) => {
                    if self.skipping > 0 {
                        let n = self.skipping;
                        self.skipping = 0;
                        return Ok(ReadLine::Oversized(n));
                    }
                    if !self.line.is_empty() {
                        return Ok(ReadLine::Line(self.take_line()));
                    }
                    return Ok(ReadLine::Eof);
                }
                Ok(n) => self.filled = n,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    return Ok(ReadLine::Idle)
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// The typed error an oversized line answers with (connection stays up).
pub(crate) fn oversized_error(bytes: usize) -> PredictError {
    PredictError::UnsupportedKernel(format!(
        "oversized line: {bytes} bytes exceeds the {MAX_LINE_BYTES}-byte cap"
    ))
}

/// One classified input line. Classification decodes the JSON exactly once
/// and picks the verb; evaluation happens later (on the surface's writer
/// thread) so per-connection response order always matches input order.
pub(crate) enum Parsed {
    /// Unparseable JSON — the abuse bucket the TCP quarantine counts.
    Malformed(String),
    Predict(Option<String>, Result<PredictRequest, PredictError>),
    Simulate(Option<String>, Result<SimulateRequest, ScenarioError>),
    Sweep(Option<String>, Result<SweepRequest, SweepError>),
    Tune(Option<String>, Result<TuneSpec, TuneError>),
    Stats(Option<String>),
}

/// Classify one non-blank line. Dispatch order: stats, sweep, tune,
/// simulate, then predict as the default — identical on both surfaces by
/// construction (this is the only classifier).
pub(crate) fn classify(line: &str) -> Parsed {
    match parse_json(line) {
        Err(e) => Parsed::Malformed(format!("malformed JSON: {e}")),
        Ok(j) => {
            if wire::is_stats_json(&j) {
                Parsed::Stats(wire::id_of(&j))
            } else if sweep::wire::is_sweep_json(&j) {
                let (id, spec) = sweep::wire::parse_sweep_json(&j);
                Parsed::Sweep(id, spec)
            } else if autotune::wire::is_tune_json(&j) {
                let (id, spec) = autotune::wire::parse_tune_json(&j);
                Parsed::Tune(id, spec)
            } else if scenario::wire::is_simulate_json(&j) {
                let (id, req) = scenario::wire::parse_request_json(&j);
                Parsed::Simulate(id, req)
            } else {
                let (id, req) = wire::parse_request_json(&j);
                Parsed::Predict(id, req)
            }
        }
    }
}

/// Deadline-aware queue admission for the stdio reader thread: a request
/// without `deadline_ms` blocks for space (backpressure propagates to the
/// peer), one with it waits at most that long and answers the typed
/// `deadline_exceeded` error. (The TCP dispatcher has its own
/// non-blocking admission loop — it must never park on one client.)
pub(crate) fn submit_predict(
    client: &Client,
    req: PredictRequest,
) -> Result<Pending, PredictError> {
    match req.opts.deadline_ms {
        None => client.submit(req),
        Some(ms) => match client.submit_deadline(req, Duration::from_millis(ms)) {
            Err(PredictError::QueueFull) => {
                client.metrics().record_deadline_exceeded();
                Err(PredictError::DeadlineExceeded)
            }
            other => other,
        },
    }
}

/// Assemble the `stats` verb's report: coordinator metrics + the live
/// queue gauge + the lock-free engine cache counters + this surface's own
/// line/connection tallies.
pub(crate) fn build_stats(
    client: &Client,
    served: u64,
    errors: u64,
    simulated: u64,
    swept: u64,
    tuned: u64,
    clients: wire::ClientStats,
) -> wire::StatsReport {
    let snap = client.metrics().snapshot();
    let es = crate::engine::PredictionEngine::global().stats();
    wire::StatsReport {
        requests: snap.requests,
        batches: snap.batches as u64,
        mean_batch: snap.mean_batch,
        rejected_requests: snap.rejected_requests,
        deadline_exceeded: snap.deadline_exceeded,
        queue_depth: client.queue_depth() as u64,
        max_queue_depth: snap.max_queue_depth as u64,
        cache_hits: es.hits,
        cache_misses: es.misses,
        served,
        errors,
        simulated,
        swept,
        tuned,
        clients,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read_all(input: &[u8], cap: usize) -> Vec<ReadLine> {
        let mut r = LineReader::new(input, cap);
        let mut out = Vec::new();
        loop {
            let item = r.read_line().unwrap();
            let eof = matches!(item, ReadLine::Eof);
            out.push(item);
            if eof {
                return out;
            }
        }
    }

    #[test]
    fn lines_split_like_bufread_lines() {
        let got = read_all(b"a\nbb\r\n\nfinal", 64);
        match &got[..] {
            [ReadLine::Line(a), ReadLine::Line(b), ReadLine::Line(c), ReadLine::Line(d), ReadLine::Eof] =>
            {
                assert_eq!(a, "a");
                assert_eq!(b, "bb");
                assert_eq!(c, "");
                assert_eq!(d, "final");
            }
            other => panic!("unexpected shape: {} items", other.len()),
        }
    }

    #[test]
    fn oversized_line_is_skipped_and_counted_stream_stays_in_sync() {
        let mut input = vec![b'x'; 1000];
        input.push(b'\n');
        input.extend_from_slice(b"ok\n");
        let got = read_all(&input, 16);
        match &got[..] {
            [ReadLine::Oversized(n), ReadLine::Line(ok), ReadLine::Eof] => {
                assert_eq!(*n, 1000);
                assert_eq!(ok, "ok");
            }
            other => panic!("unexpected shape: {} items", other.len()),
        }
    }

    #[test]
    fn oversized_at_eof_still_reports() {
        let got = read_all(&vec![b'y'; 500], 16);
        assert!(matches!(&got[..], [ReadLine::Oversized(500), ReadLine::Eof]));
    }

    #[test]
    fn invalid_utf8_is_replaced_not_fatal() {
        let got = read_all(b"\xff\xfe\n", 64);
        match &got[..] {
            [ReadLine::Line(s), ReadLine::Eof] => {
                assert!(!s.is_empty(), "lossy replacement, not silence")
            }
            other => panic!("unexpected shape: {} items", other.len()),
        }
    }

    #[test]
    fn classify_dispatches_all_verbs() {
        assert!(matches!(classify("not json"), Parsed::Malformed(_)));
        assert!(matches!(classify(r#"{"op":"stats"}"#), Parsed::Stats(None)));
        assert!(matches!(
            classify(r#"{"id":"w","op":"sweep","sweep":{}}"#),
            Parsed::Sweep(Some(_), _)
        ));
        assert!(matches!(
            classify(r#"{"id":"t","op":"tune","tune":{}}"#),
            Parsed::Tune(Some(_), Ok(_))
        ));
        assert!(matches!(
            classify(r#"{"op":"simulate","scenario":{"model":"m","gpu":"A100"}}"#),
            Parsed::Simulate(None, _)
        ));
        assert!(matches!(
            classify(r#"{"gpu":"A100","kernel":{"type":"rmsnorm","seq":4,"dim":8}}"#),
            Parsed::Predict(None, Ok(_))
        ));
    }
}
