//! Dynamic batcher: collects requests until either the batch-size target or
//! the deadline is hit — the standard latency/throughput knob of serving
//! systems (vLLM/SGLang routers), applied here to MLP inference batches.
//! Draws from the coordinator's bounded [`Bounded`] queue, so collecting a
//! batch is also what frees space for blocked producers.

use super::queue::{Bounded, Pop};
use std::time::{Duration, Instant};

/// Drain up to `max_batch` items from `q`, waiting at most `deadline` from
/// the arrival of the first item. The `bool` is the terminal signal: the
/// queue is closed *and* fully drained (graceful shutdown finishes the
/// returned batch first).
pub fn collect_batch<T>(q: &Bounded<T>, max_batch: usize, deadline: Duration) -> (Vec<T>, bool) {
    let mut batch = Vec::new();
    // block for the first item
    match q.pop() {
        Some(item) => batch.push(item),
        None => return (batch, true),
    }
    let until = Instant::now() + deadline;
    while batch.len() < max_batch {
        match q.pop_until(until) {
            Pop::Item(item) => batch.push(item),
            Pop::Timeout => break,
            Pop::Closed => return (batch, true),
        }
    }
    (batch, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_trigger() {
        let q = Bounded::new(16);
        for i in 0..10 {
            q.try_push(i).unwrap();
        }
        let (batch, closed) = collect_batch(&q, 4, Duration::from_millis(50));
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(!closed);
    }

    #[test]
    fn deadline_trigger() {
        let q = Bounded::new(16);
        q.try_push(1).unwrap();
        let t0 = Instant::now();
        let (batch, closed) = collect_batch(&q, 100, Duration::from_millis(20));
        assert_eq!(batch, vec![1]);
        assert!(!closed);
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn close_reported_after_drain() {
        let q: Bounded<u32> = Bounded::new(4);
        q.close();
        let (batch, closed) = collect_batch(&q, 4, Duration::from_millis(5));
        assert!(batch.is_empty());
        assert!(closed);

        // a closed queue still hands out what it accepted first
        let q = Bounded::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        let (batch, closed) = collect_batch(&q, 4, Duration::from_millis(5));
        assert_eq!(batch, vec![1, 2]);
        assert!(closed);
    }
}
