//! Dynamic batcher: collects requests until either the batch-size target or
//! the deadline is hit — the standard latency/throughput knob of serving
//! systems (vLLM/SGLang routers), applied here to MLP inference batches.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Drain up to `max_batch` items from `rx`, waiting at most `deadline` from
/// the arrival of the first item. Returns an empty vec on disconnect.
pub fn collect_batch<T>(
    rx: &Receiver<T>,
    max_batch: usize,
    deadline: Duration,
) -> (Vec<T>, bool) {
    let mut batch = Vec::new();
    // block for the first item
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return (batch, true),
    }
    let t0 = Instant::now();
    while batch.len() < max_batch {
        let left = deadline.saturating_sub(t0.elapsed());
        if left.is_zero() {
            break;
        }
        match rx.recv_timeout(left) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return (batch, true),
        }
    }
    (batch, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn size_trigger() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let (batch, closed) = collect_batch(&rx, 4, Duration::from_millis(50));
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(!closed);
    }

    #[test]
    fn deadline_trigger() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let (batch, closed) = collect_batch(&rx, 100, Duration::from_millis(20));
        assert_eq!(batch, vec![1]);
        assert!(!closed);
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn disconnect_reported() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let (batch, closed) = collect_batch(&rx, 4, Duration::from_millis(5));
        assert!(batch.is_empty());
        assert!(closed);
    }
}
