//! Layer-3 coordinator: prediction-as-a-service.
//!
//! SynPerf's real-time use case (§IV: "enabling real-time predictions") is
//! served by a coordinator that accepts prediction requests, batches them
//! dynamically (size- or deadline-triggered, vLLM-router style), routes each
//! batch to the per-kernel-category MLP executable, and streams results
//! back — all in rust on top of std::thread + mpsc (the offline vendor set
//! has no tokio; the event loop is a hand-rolled deadline batcher).

pub mod batcher;
pub mod metrics;
pub mod service;

pub use metrics::Metrics;
pub use service::{PredictionService, Request, ServiceConfig};
