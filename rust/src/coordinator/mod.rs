//! Layer-3 coordinator: prediction-as-a-service speaking protocol v1
//! ([`crate::api`]).
//!
//! SynPerf's real-time use case (§IV: "enabling real-time predictions") is
//! served by a coordinator that accepts typed prediction requests over a
//! **bounded** queue (explicit backpressure: `try_predict` →
//! `PredictError::QueueFull`, blocking submits wait for space), batches
//! them dynamically (size- or deadline-triggered, vLLM-router style),
//! routes each batch through the one shared request path
//! ([`crate::api::predict_batch`]), and answers with provenance-carrying
//! [`crate::api::PredictResponse`]s — all on std::thread + condvars (the
//! offline vendor set has no tokio; the event loop is a hand-rolled
//! deadline batcher).

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod service;

pub use metrics::Metrics;
pub use service::{Client, Pending, PredictionService, Request, ServiceConfig};
