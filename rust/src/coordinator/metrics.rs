//! Service metrics: request counts, latency percentiles, batch-size
//! distribution, the engine-level observability counters (analysis cache
//! hits/misses, per-kind routing occupancy), and the backpressure gauges of
//! the bounded queue (`queue_depth`, `rejected_requests`) — enough to
//! report the coordinator benches and to assert queue behavior in tests.

use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<f64>,
    batch_sizes: Vec<usize>,
    requests: u64,
    cache_hits: u64,
    cache_misses: u64,
    /// One entry per processed batch: number of per-kind MLP sub-batches.
    kind_groups: Vec<usize>,
    /// Requests refused with `QueueFull` (backpressure made visible).
    rejected: u64,
    /// Requests whose admission deadline expired while the queue stayed
    /// saturated (`PredictError::DeadlineExceeded`).
    deadline_exceeded: u64,
    /// Backlog sampled after each batch collection.
    queue_depth_last: usize,
    queue_depth_max: usize,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: usize,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Decomposition/feature cache hits and misses across all requests.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Mean rows per per-kind MLP sub-batch (batch occupancy): how well the
    /// dynamic batcher fills the per-category forward passes.
    pub mean_kind_batch: f64,
    /// Requests refused with `PredictError::QueueFull`.
    pub rejected_requests: u64,
    /// Requests answered `PredictError::DeadlineExceeded` (their
    /// `deadline_ms` expired before queue admission).
    pub deadline_exceeded: u64,
    /// Bounded-queue backlog: last sample and high-water mark.
    pub queue_depth: usize,
    pub max_queue_depth: usize,
}

impl Snapshot {
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

impl Metrics {
    pub fn record_batch(&self, batch_size: usize, latency: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.requests += batch_size as u64;
        g.batch_sizes.push(batch_size);
        g.latencies_us.push(latency.as_secs_f64() * 1e6);
    }

    /// Record one batched prediction round: cache outcome per request and
    /// how many per-kind sub-batches the round was routed into.
    pub fn record_route(&self, cache_hits: usize, cache_misses: usize, kind_groups: usize) {
        let mut g = self.inner.lock().unwrap();
        g.cache_hits += cache_hits as u64;
        g.cache_misses += cache_misses as u64;
        g.kind_groups.push(kind_groups);
    }

    /// One request bounced off the full queue.
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One request's admission deadline expired (also counted rejected —
    /// `deadline_exceeded` is the subset of `rejected_requests` that
    /// carried a `deadline_ms`).
    pub fn record_deadline_exceeded(&self) {
        self.inner.lock().unwrap().deadline_exceeded += 1;
    }

    /// Sample the bounded-queue backlog (called by the service loop after
    /// each batch collection).
    pub fn record_queue_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.queue_depth_last = depth;
        g.queue_depth_max = g.queue_depth_max.max(depth);
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            lat[((lat.len() - 1) as f64 * q) as usize]
        };
        let total_groups: usize = g.kind_groups.iter().sum();
        Snapshot {
            requests: g.requests,
            batches: g.batch_sizes.len(),
            mean_batch: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
            },
            p50_us: pct(0.5),
            p99_us: pct(0.99),
            cache_hits: g.cache_hits,
            cache_misses: g.cache_misses,
            mean_kind_batch: if total_groups == 0 {
                0.0
            } else {
                (g.cache_hits + g.cache_misses) as f64 / total_groups as f64
            },
            rejected_requests: g.rejected,
            deadline_exceeded: g.deadline_exceeded,
            queue_depth: g.queue_depth_last,
            max_queue_depth: g.queue_depth_max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_batch(4, Duration::from_micros(100));
        m.record_batch(8, Duration::from_micros(300));
        let s = m.snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert!(s.p99_us >= s.p50_us);
    }

    #[test]
    fn route_counters_aggregate() {
        let m = Metrics::default();
        m.record_route(3, 1, 2);
        m.record_route(5, 3, 2);
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 8);
        assert_eq!(s.cache_misses, 4);
        assert!((s.cache_hit_rate() - 8.0 / 12.0).abs() < 1e-12);
        // 12 routed rows over 4 per-kind sub-batches
        assert!((s.mean_kind_batch - 3.0).abs() < 1e-12);
    }

    #[test]
    fn backpressure_gauges() {
        let m = Metrics::default();
        m.record_rejected();
        m.record_rejected();
        m.record_queue_depth(7);
        m.record_queue_depth(3);
        m.record_deadline_exceeded();
        let s = m.snapshot();
        assert_eq!(s.rejected_requests, 2);
        assert_eq!(s.deadline_exceeded, 1);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.max_queue_depth, 7);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.cache_hits + s.cache_misses, 0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!(s.mean_kind_batch, 0.0);
        assert_eq!(s.rejected_requests, 0);
        assert_eq!((s.queue_depth, s.max_queue_depth), (0, 0));
    }
}
