//! Service metrics: request counts, latency percentiles, batch-size
//! distribution — enough to report the coordinator benches.

use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<f64>,
    batch_sizes: Vec<usize>,
    requests: u64,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: usize,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl Metrics {
    pub fn record_batch(&self, batch_size: usize, latency: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.requests += batch_size as u64;
        g.batch_sizes.push(batch_size);
        g.latencies_us.push(latency.as_secs_f64() * 1e6);
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            lat[((lat.len() - 1) as f64 * q) as usize]
        };
        Snapshot {
            requests: g.requests,
            batches: g.batch_sizes.len(),
            mean_batch: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
            },
            p50_us: pct(0.5),
            p99_us: pct(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_batch(4, Duration::from_micros(100));
        m.record_batch(8, Duration::from_micros(300));
        let s = m.snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert!(s.p99_us >= s.p50_us);
    }
}
