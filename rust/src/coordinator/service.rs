//! The prediction service: a leader thread owns the per-kernel-category
//! Predictors (constructed on the service thread; the routing pass may
//! still fan per-kind forwards out over scoped workers that borrow them)
//! and runs the dynamic-batch
//! loop; clients hold a cheap cloneable [`Client`] handle speaking protocol
//! v1. Typed [`PredictRequest`] -> bounded queue -> [batcher] ->
//! [`crate::api::predict_batch_threads`] (sharded-cache analyze + per-kind
//! batched MLP routing, fanned out over `ServiceConfig::threads` workers)
//! -> typed [`PredictResponse`] with provenance.
//!
//! Backpressure is explicit: the request queue is bounded
//! (`ServiceConfig::queue_cap`); [`Client::try_predict`] answers
//! [`PredictError::QueueFull`] immediately, the blocking calls wait for
//! space (optionally up to a deadline) instead of growing an unbounded
//! backlog. Shutdown is graceful: the queue closes, everything already
//! accepted is answered, then the thread exits.

use super::batcher::collect_batch;
use super::metrics::Metrics;
use super::queue::{Bounded, PushError};
use crate::api::{self, ModelBundle, PredictError, PredictRequest, PredictResponse};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One queued request: the typed protocol request plus the responder the
/// service answers on.
pub struct Request {
    pub req: PredictRequest,
    pub(crate) resp: Sender<Result<PredictResponse, PredictError>>,
}

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Dynamic batch size target.
    pub max_batch: usize,
    /// Dynamic batch deadline: max wait from the first queued request.
    pub deadline: Duration,
    /// Bounded request-queue capacity (the backpressure knob).
    pub queue_cap: usize,
    /// Worker threads for the per-batch routing pass (cached analyze +
    /// per-kind MLP forwards fanned out over the engine's scoped-thread
    /// `par_map`). Batches below ~32 requests per worker run serially
    /// regardless, so small steady-state batches never pay thread-spawn
    /// latency. Latencies are thread-count independent; this is the
    /// `serve --threads` knob. Defaults to available parallelism.
    pub threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 256,
            deadline: Duration::from_millis(2),
            queue_cap: 1024,
            threads: crate::engine::par::default_threads(),
        }
    }
}

/// A future-style handle to one in-flight prediction: obtain from the
/// submit calls, redeem with [`Pending::wait`].
pub struct Pending {
    rx: Receiver<Result<PredictResponse, PredictError>>,
}

impl Pending {
    /// Block until the service answers. A service that died before
    /// answering reports [`PredictError::Shutdown`].
    pub fn wait(self) -> Result<PredictResponse, PredictError> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(PredictError::Shutdown),
        }
    }
}

/// Cheap cloneable client handle onto a running [`PredictionService`].
#[derive(Clone)]
pub struct Client {
    queue: Arc<Bounded<Request>>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Non-blocking submit: [`PredictError::QueueFull`] the instant the
    /// bounded queue is at capacity.
    pub fn try_predict(&self, req: PredictRequest) -> Result<Pending, PredictError> {
        req.validate()?;
        let (tx, rx) = channel();
        match self.queue.try_push(Request { req, resp: tx }) {
            Ok(()) => Ok(Pending { rx }),
            Err(PushError::Full(_)) => {
                self.metrics.record_rejected();
                Err(PredictError::QueueFull)
            }
            Err(PushError::Closed(_)) => Err(PredictError::Shutdown),
        }
    }

    /// [`Client::try_predict`] without the rejected-request metric on
    /// `Full`: the TCP admission dispatcher probes the queue every tick
    /// while a head-of-line request waits, and only the *terminal*
    /// outcome of that retry loop should count — the dispatcher records
    /// it explicitly when it gives up.
    pub(crate) fn try_predict_silent(&self, req: PredictRequest) -> Result<Pending, PredictError> {
        req.validate()?;
        let (tx, rx) = channel();
        match self.queue.try_push(Request { req, resp: tx }) {
            Ok(()) => Ok(Pending { rx }),
            Err(PushError::Full(_)) => Err(PredictError::QueueFull),
            Err(PushError::Closed(_)) => Err(PredictError::Shutdown),
        }
    }

    /// Blocking submit: wait for queue space as long as it takes
    /// (backpressure propagates to the producer).
    pub fn submit(&self, req: PredictRequest) -> Result<Pending, PredictError> {
        self.submit_wait(req, None)
    }

    /// Blocking submit with a deadline: [`PredictError::QueueFull`] if the
    /// queue stays saturated for the whole `deadline`.
    pub fn submit_deadline(
        &self,
        req: PredictRequest,
        deadline: Duration,
    ) -> Result<Pending, PredictError> {
        self.submit_wait(req, Some(deadline))
    }

    fn submit_wait(
        &self,
        req: PredictRequest,
        deadline: Option<Duration>,
    ) -> Result<Pending, PredictError> {
        req.validate()?;
        let (tx, rx) = channel();
        match self.queue.push_wait(Request { req, resp: tx }, deadline) {
            Ok(()) => Ok(Pending { rx }),
            Err(PushError::Full(_)) => {
                self.metrics.record_rejected();
                Err(PredictError::QueueFull)
            }
            Err(PushError::Closed(_)) => Err(PredictError::Shutdown),
        }
    }

    /// Blocking single prediction (submit + wait).
    pub fn predict(&self, req: PredictRequest) -> Result<PredictResponse, PredictError> {
        self.submit(req)?.wait()
    }

    /// Blocking single prediction with an enqueue deadline.
    pub fn predict_deadline(
        &self,
        req: PredictRequest,
        deadline: Duration,
    ) -> Result<PredictResponse, PredictError> {
        self.submit_deadline(req, deadline)?.wait()
    }

    /// Submit a whole batch (blocking on space per request), then wait for
    /// every answer. Results are in input order.
    pub fn predict_batch(
        &self,
        reqs: Vec<PredictRequest>,
    ) -> Vec<Result<PredictResponse, PredictError>> {
        let pendings: Vec<Result<Pending, PredictError>> =
            reqs.into_iter().map(|r| self.submit(r)).collect();
        pendings
            .into_iter()
            .map(|p| match p {
                Ok(pending) => pending.wait(),
                Err(e) => Err(e),
            })
            .collect()
    }

    /// Live bounded-queue backlog.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

pub struct PredictionService {
    queue: Arc<Bounded<Request>>,
    pub metrics: Arc<Metrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Spawn the service thread. PJRT executables are not `Send`, so the
    /// per-kernel-category model bundle is constructed *on* the service
    /// thread by `factory` (untrained categories answer with the
    /// theoretical roof — the protocol's documented degraded mode, visible
    /// in `PredictResponse::provenance`).
    pub fn spawn<F>(factory: F, cfg: ServiceConfig) -> PredictionService
    where
        F: FnOnce() -> ModelBundle + Send + 'static,
    {
        let queue = Arc::new(Bounded::new(cfg.queue_cap));
        let metrics = Arc::new(Metrics::default());
        let (q2, m2) = (queue.clone(), metrics.clone());
        let handle = std::thread::spawn(move || {
            // close the queue even if the factory (or the loop) panics:
            // otherwise blocked submitters would wait forever on a dead
            // service instead of seeing PredictError::Shutdown
            struct CloseOnExit(Arc<Bounded<Request>>);
            impl Drop for CloseOnExit {
                fn drop(&mut self) {
                    self.0.close();
                }
            }
            let _close_guard = CloseOnExit(q2.clone());
            let bundle = factory();
            service_loop(&q2, &bundle, &cfg, &m2)
        });
        PredictionService { queue, metrics, handle: Some(handle) }
    }

    /// A cloneable protocol-v1 client onto this service.
    pub fn client(&self) -> Client {
        Client { queue: self.queue.clone(), metrics: self.metrics.clone() }
    }

    /// Convenience: blocking single prediction through a throwaway client.
    pub fn predict(&self, req: PredictRequest) -> Result<PredictResponse, PredictError> {
        self.client().predict(req)
    }

    /// Live bounded-queue backlog.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Graceful shutdown: refuse new requests, answer everything already
    /// accepted, join the service thread.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn service_loop(
    queue: &Bounded<Request>,
    bundle: &ModelBundle,
    cfg: &ServiceConfig,
    metrics: &Metrics,
) {
    loop {
        let (batch, closed) = collect_batch(queue, cfg.max_batch, cfg.deadline);
        if !batch.is_empty() {
            metrics.record_queue_depth(queue.len());
            process_batch(bundle, batch, metrics, cfg.threads);
        }
        if closed {
            return;
        }
    }
}

fn process_batch(bundle: &ModelBundle, batch: Vec<Request>, metrics: &Metrics, threads: usize) {
    let t0 = Instant::now();
    let mut reqs = Vec::with_capacity(batch.len());
    let mut responders = Vec::with_capacity(batch.len());
    for r in batch {
        reqs.push(r.req);
        responders.push(r.resp);
    }
    let report = api::predict_batch_threads(bundle, &reqs, threads);
    // record before answering: a client that sees its response also sees
    // the metrics that accounted for it
    metrics.record_route(report.cache_hits, report.cache_misses, report.kind_groups);
    metrics.record_batch(reqs.len(), t0.elapsed());
    for (resp, result) in responders.into_iter().zip(report.results) {
        // receiver may have gone away; ignore
        let _ = resp.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Source;
    use crate::hw::gpu_by_name;
    use crate::kernels::{DType, KernelConfig};

    fn svc() -> PredictionService {
        PredictionService::spawn(ModelBundle::default, ServiceConfig::default())
    }

    #[test]
    fn degraded_mode_answers_roofline_with_provenance() {
        // no trained models: service still answers, and says so
        let svc = svc();
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = KernelConfig::Gemm { m: 2048, n: 2048, k: 2048, dtype: DType::Bf16 };
        let resp = svc.predict(PredictRequest::new(cfg, gpu)).unwrap();
        assert!(resp.latency_sec > 0.0 && resp.latency_sec.is_finite());
        assert_eq!(resp.provenance.source, Source::Roofline);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 1);
        svc.shutdown();
    }

    #[test]
    fn batches_multiple_clients() {
        let svc = svc();
        let client = svc.client();
        let gpu = gpu_by_name("H800").unwrap();
        let pendings: Vec<Pending> = (0..64)
            .map(|i| {
                client
                    .submit(PredictRequest::new(
                        KernelConfig::RmsNorm { seq: 128 + i, dim: 4096 },
                        gpu.clone(),
                    ))
                    .unwrap()
            })
            .collect();
        for p in pendings {
            let resp = p.wait().unwrap();
            assert!(resp.latency_sec > 0.0);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 64);
        assert!(snap.mean_batch > 1.5, "should have batched: {snap:?}");
        svc.shutdown();
    }

    #[test]
    fn repeated_launches_hit_the_analysis_cache() {
        let svc = svc();
        let gpu = gpu_by_name("L40").unwrap();
        // deliberately odd shape: unique to this test, so the first submit
        // misses and every repeat must hit the decomposition cache
        let cfg = KernelConfig::Gemm { m: 1237, n: 4211, k: 773, dtype: DType::Bf16 };
        for i in 0..5 {
            let resp = svc.predict(PredictRequest::new(cfg.clone(), gpu.clone())).unwrap();
            assert!(resp.latency_sec > 0.0);
            assert_eq!(resp.provenance.cache_hit, i > 0, "repeat {i}");
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.cache_hits + snap.cache_misses, 5);
        assert!(
            snap.cache_hits >= 4,
            "repeats must hit the cache: {} hits / {} misses",
            snap.cache_hits,
            snap.cache_misses
        );
        assert!(snap.mean_kind_batch >= 1.0);
        svc.shutdown();
    }

    #[test]
    fn invalid_request_fails_fast_without_queueing() {
        let svc = svc();
        let gpu = gpu_by_name("A40").unwrap();
        let bad = PredictRequest::new(
            KernelConfig::Gemm { m: 0, n: 16, k: 16, dtype: DType::Bf16 },
            gpu,
        );
        let err = svc.client().try_predict(bad).unwrap_err();
        assert_eq!(err.code(), "unsupported_kernel");
        assert_eq!(svc.queue_depth(), 0);
        svc.shutdown();
    }

    #[test]
    fn client_after_shutdown_gets_shutdown_error() {
        let svc = svc();
        let client = svc.client();
        let gpu = gpu_by_name("A100").unwrap();
        svc.shutdown();
        let err = client
            .predict(PredictRequest::new(
                KernelConfig::RmsNorm { seq: 64, dim: 512 },
                gpu,
            ))
            .unwrap_err();
        assert_eq!(err, PredictError::Shutdown);
    }

    #[test]
    fn panicking_factory_closes_the_queue() {
        // a factory that dies (e.g. missing artifacts) must surface as the
        // typed Shutdown error, not leave blocking submitters hanging
        let svc = PredictionService::spawn(|| panic!("factory died"), ServiceConfig::default());
        let client = svc.client();
        let gpu = gpu_by_name("H20").unwrap();
        let err = client
            .predict(PredictRequest::new(
                KernelConfig::RmsNorm { seq: 8, dim: 64 },
                gpu,
            ))
            .unwrap_err();
        assert_eq!(err, PredictError::Shutdown);
        svc.shutdown();
    }

    #[test]
    fn shutdown_joins() {
        svc().shutdown();
    }
}
