//! The prediction service: a leader thread owns the per-kernel-category
//! Predictors (PJRT executables are not Sync) and runs the dynamic-batch
//! loop; clients hold a cheap cloneable handle and block on their own
//! response channel. Request -> [batcher] -> shared [`PredictionEngine`]
//! (cached decompose/schedule/featurize + per-kind batched MLP routing) ->
//! respond.

use super::batcher::collect_batch;
use super::metrics::Metrics;
use crate::engine::PredictionEngine;
use crate::hw::GpuSpec;
use crate::kernels::{KernelConfig, KernelKind};
use crate::mlp::Predictor;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A prediction request: a kernel launch on a GPU; the service decomposes,
/// schedules, featurizes and predicts latency.
pub struct Request {
    pub cfg: KernelConfig,
    pub gpu: GpuSpec,
    pub resp: Sender<f64>,
}

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub deadline: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { max_batch: 256, deadline: Duration::from_millis(2) }
    }
}

pub struct PredictionService {
    tx: Sender<Request>,
    pub metrics: Arc<Metrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Spawn the service thread. PJRT executables are not `Send`, so the
    /// per-kernel-category Predictors are constructed *on* the service
    /// thread by `factory` (untrained categories answer with the
    /// theoretical roof — documented degraded mode). The analytical front
    /// half runs on the process-wide [`PredictionEngine`], so repeated
    /// launches across batches (and across services) hit its cache.
    pub fn spawn<F>(factory: F, cfg: ServiceConfig) -> PredictionService
    where
        F: FnOnce() -> HashMap<KernelKind, Predictor> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let handle = std::thread::spawn(move || {
            let models = factory();
            service_loop(rx, models, cfg, m2)
        });
        PredictionService { tx, metrics, handle: Some(handle) }
    }

    /// Client handle: submit a request, receive the latency via the channel.
    pub fn submit(&self, cfg: KernelConfig, gpu: GpuSpec) -> Receiver<f64> {
        let (resp_tx, resp_rx) = channel();
        self.tx
            .send(Request { cfg, gpu, resp: resp_tx })
            .expect("service thread alive");
        resp_rx
    }

    /// Convenience: blocking single prediction.
    pub fn predict(&self, cfg: KernelConfig, gpu: &GpuSpec) -> Result<f64> {
        let rx = self.submit(cfg, gpu.clone());
        Ok(rx.recv()?)
    }

    /// Graceful shutdown.
    pub fn shutdown(mut self) {
        drop(self.tx);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

fn service_loop(
    rx: Receiver<Request>,
    models: HashMap<KernelKind, Predictor>,
    cfg: ServiceConfig,
    metrics: Arc<Metrics>,
) {
    let engine = PredictionEngine::global();
    loop {
        let (batch, closed) = collect_batch(&rx, cfg.max_batch, cfg.deadline);
        if !batch.is_empty() {
            let t0 = Instant::now();
            let n = batch.len();
            process_batch(engine, batch, &models, &metrics);
            metrics.record_batch(n, t0.elapsed());
        }
        if closed {
            return;
        }
    }
}

fn process_batch(
    engine: &PredictionEngine,
    batch: Vec<Request>,
    models: &HashMap<KernelKind, Predictor>,
    metrics: &Metrics,
) {
    let mut reqs = Vec::with_capacity(batch.len());
    let mut responders = Vec::with_capacity(batch.len());
    for r in batch {
        reqs.push((r.cfg, r.gpu));
        responders.push(r.resp);
    }
    // infallible: a category whose model is missing or whose forward fails
    // answers with the theoretical roof, without degrading other categories
    let out = engine.predict_batch(models, &reqs);
    metrics.record_route(out.cache_hits, out.cache_misses, out.kind_groups);
    for (resp, lat) in responders.into_iter().zip(out.latencies) {
        // receiver may have gone away; ignore
        let _ = resp.send(lat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::DType;

    #[test]
    fn degraded_mode_answers_roofline() {
        // no trained models: service still answers with theory roof
        let svc = PredictionService::spawn(HashMap::new, ServiceConfig::default());
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = KernelConfig::Gemm { m: 2048, n: 2048, k: 2048, dtype: DType::Bf16 };
        let lat = svc.predict(cfg, &gpu).unwrap();
        assert!(lat > 0.0 && lat.is_finite());
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 1);
        svc.shutdown();
    }

    #[test]
    fn batches_multiple_clients() {
        let svc = PredictionService::spawn(HashMap::new, ServiceConfig::default());
        let gpu = gpu_by_name("H800").unwrap();
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                svc.submit(
                    KernelConfig::RmsNorm { seq: 128 + i, dim: 4096 },
                    gpu.clone(),
                )
            })
            .collect();
        for rx in rxs {
            let v = rx.recv().unwrap();
            assert!(v > 0.0);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 64);
        assert!(snap.mean_batch > 1.5, "should have batched: {snap:?}");
        svc.shutdown();
    }

    #[test]
    fn repeated_launches_hit_the_analysis_cache() {
        let svc = PredictionService::spawn(HashMap::new, ServiceConfig::default());
        let gpu = gpu_by_name("L40").unwrap();
        // deliberately odd shape: unique to this test, so the first submit
        // misses and every repeat must hit the decomposition cache
        let cfg = KernelConfig::Gemm { m: 1237, n: 4211, k: 773, dtype: DType::Bf16 };
        for _ in 0..5 {
            let v = svc.predict(cfg.clone(), &gpu).unwrap();
            assert!(v > 0.0);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.cache_hits + snap.cache_misses, 5);
        assert!(
            snap.cache_hits >= 4,
            "repeats must hit the cache: {} hits / {} misses",
            snap.cache_hits,
            snap.cache_misses
        );
        assert!(snap.mean_kind_batch >= 1.0);
        svc.shutdown();
    }

    #[test]
    fn shutdown_joins() {
        let svc = PredictionService::spawn(HashMap::new, ServiceConfig::default());
        svc.shutdown();
    }
}
