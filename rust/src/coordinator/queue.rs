//! Bounded MPMC request queue (Mutex + Condvar; the offline vendor set has
//! no crossbeam). This replaces the unbounded `std::sync::mpsc` channel the
//! service used to accept requests on: under sustained overload the old
//! queue grew without limit, while this one makes saturation an explicit,
//! observable outcome ([`PushError::Full`] → `PredictError::QueueFull`).
//!
//! Close semantics support graceful drain: after [`Bounded::close`] no new
//! item can enter, but poppers keep receiving queued items until the buffer
//! is empty — the service loop answers everything already accepted before
//! exiting.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused; the item is handed back to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at capacity (and stayed there for the whole timeout,
    /// for the waiting push variants).
    Full(T),
    /// The queue was closed; no further items are accepted.
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
#[derive(Debug)]
pub enum Pop<T> {
    Item(T),
    Timeout,
    /// Closed *and* drained — the terminal state.
    Closed,
}

struct State<T> {
    buf: VecDeque<T>,
    closed: bool,
    /// Pushers currently blocked in `push_wait` (incremented under the lock
    /// before waiting on `not_full`). Poppers only signal `not_full` when
    /// this is non-zero, so the hot drain path stops paying a syscall per
    /// pop when nobody can be waiting.
    push_waiters: usize,
}

pub struct Bounded<T> {
    state: Mutex<State<T>>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Bounded<T> {
    pub fn new(cap: usize) -> Bounded<T> {
        let cap = cap.max(1);
        Bounded {
            state: Mutex::new(State {
                buf: VecDeque::with_capacity(cap.min(4096)),
                closed: false,
                push_waiters: 0,
            }),
            cap,
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current depth — the live `queue_depth` gauge.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.lock().unwrap().buf.is_empty()
    }

    /// Non-blocking push: [`PushError::Full`] the instant the queue is at
    /// capacity — the backpressure edge of `try_predict`.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.state.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.buf.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.buf.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: wait for space up to `timeout` (`None` = as long as
    /// it takes). Returns [`PushError::Full`] only when the timeout expires
    /// with the queue still at capacity.
    ///
    /// Wakeup protocol (audited for lost wakeups with multiple blocked
    /// pushers): a slot is freed only by a pop, and every pop that frees a
    /// slot while `push_waiters > 0` issues exactly one `notify_one` — one
    /// signal per freed slot, so N frees wake up to N pushers. A woken
    /// pusher re-checks space in the loop; if a `try_push` stole the slot
    /// first, the queue is full again and no free slot is stranded. Exits
    /// that consume a notification without pushing are safe too: the
    /// `closed` exit is covered by `close()`'s `notify_all`, and the
    /// timeout exit only returns Full while the queue is at capacity (a
    /// woken-but-expired pusher still takes a free slot if one exists).
    /// The waiter count is mutated only under the mutex and `Condvar::wait`
    /// releases it atomically, so a popper can never observe zero waiters
    /// while a pusher is between deciding to wait and waiting.
    pub fn push_wait(&self, item: T, timeout: Option<Duration>) -> Result<(), PushError<T>> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut g = self.state.lock().unwrap();
        loop {
            if g.closed {
                return Err(PushError::Closed(item));
            }
            if g.buf.len() < self.cap {
                g.buf.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g.push_waiters += 1;
            match deadline {
                None => g = self.not_full.wait(g).unwrap(),
                Some(d) => {
                    let left = d.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        g.push_waiters -= 1;
                        return Err(PushError::Full(item));
                    }
                    g = self.not_full.wait_timeout(g, left).unwrap().0;
                }
            }
            g.push_waiters -= 1;
        }
    }

    /// Blocking pop; `None` means closed and fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(item) = g.buf.pop_front() {
                // signal only when a pusher is actually parked — the
                // uncontended drain path used to notify_one on every pop
                let wake = g.push_waiters > 0;
                drop(g);
                if wake {
                    self.not_full.notify_one();
                }
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking pop: [`Pop::Timeout`] means empty-but-open (nothing
    /// available right now), [`Pop::Closed`] means closed and fully
    /// drained — the round-robin TCP dispatcher's probe, which must never
    /// park on one client's queue while others have work.
    pub fn try_pop(&self) -> Pop<T> {
        let mut g = self.state.lock().unwrap();
        if let Some(item) = g.buf.pop_front() {
            let wake = g.push_waiters > 0;
            drop(g);
            if wake {
                self.not_full.notify_one();
            }
            return Pop::Item(item);
        }
        if g.closed {
            return Pop::Closed;
        }
        Pop::Timeout
    }

    /// Pop with a deadline (the batcher's intra-batch wait).
    pub fn pop_until(&self, deadline: Instant) -> Pop<T> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(item) = g.buf.pop_front() {
                let wake = g.push_waiters > 0;
                drop(g);
                if wake {
                    self.not_full.notify_one();
                }
                return Pop::Item(item);
            }
            if g.closed {
                return Pop::Closed;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Pop::Timeout;
            }
            g = self.not_empty.wait_timeout(g, left).unwrap().0;
        }
    }

    /// Close the queue: pending and future pushes fail, pops drain what was
    /// already accepted and then report [`Pop::Closed`].
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn try_push_reports_full_not_blocks() {
        let q = Bounded::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn push_wait_times_out_when_saturated() {
        let q = Bounded::new(1);
        q.try_push(7).unwrap();
        let t0 = Instant::now();
        let res = q.push_wait(8, Some(Duration::from_millis(30)));
        assert!(matches!(res, Err(PushError::Full(8))));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn push_wait_unblocks_on_pop() {
        let q = Arc::new(Bounded::new(1));
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push_wait(2, Some(Duration::from_secs(5))));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert!(h.join().unwrap().is_ok());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = Bounded::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert!(matches!(q.pop_until(Instant::now()), Pop::Closed));
    }

    #[test]
    fn pop_until_times_out_on_empty() {
        let q: Bounded<u32> = Bounded::new(4);
        let t0 = Instant::now();
        assert!(matches!(q.pop_until(t0 + Duration::from_millis(20)), Pop::Timeout));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn multi_pusher_stress_every_accepted_item_is_popped() {
        // 8 pushers hammer a capacity-2 queue against one deliberately
        // stalling popper, mixing unbounded and timed waits. The contract
        // under test is the wakeup protocol: no accepted item may be lost
        // and no pusher may be stranded (an unbounded push_wait that never
        // wakes would hang this test).
        let q = Arc::new(Bounded::new(2));
        let accepted = Arc::new(Mutex::new(Vec::new()));
        let popped = Arc::new(Mutex::new(Vec::new()));
        let popper = {
            let (q, popped) = (q.clone(), popped.clone());
            std::thread::spawn(move || {
                while let Some(v) = q.pop() {
                    popped.lock().unwrap().push(v);
                    if v % 13 == 0 {
                        // stall so pushers pile up on the full queue
                        std::thread::sleep(Duration::from_micros(300));
                    }
                }
            })
        };
        let pushers: Vec<_> = (0..8u32)
            .map(|t| {
                let (q, accepted) = (q.clone(), accepted.clone());
                std::thread::spawn(move || {
                    for i in 0..200u32 {
                        let item = t * 1_000 + i;
                        let timeout = if t % 2 == 0 {
                            None // must eventually succeed or the test hangs
                        } else {
                            Some(Duration::from_millis(2))
                        };
                        match q.push_wait(item, timeout) {
                            Ok(()) => accepted.lock().unwrap().push(item),
                            Err(PushError::Full(_)) => {} // timed out, never accepted
                            Err(PushError::Closed(_)) => panic!("closed while pushers live"),
                        }
                    }
                })
            })
            .collect();
        for h in pushers {
            h.join().unwrap();
        }
        q.close();
        popper.join().unwrap();
        let mut acc = accepted.lock().unwrap().clone();
        let mut got = popped.lock().unwrap().clone();
        acc.sort_unstable();
        got.sort_unstable();
        assert!(acc.len() >= 4 * 200, "unbounded pushers must all be accepted");
        assert_eq!(acc, got, "every accepted item must be popped exactly once");
    }

    #[test]
    fn close_wakes_blocked_pusher() {
        let q = Arc::new(Bounded::new(1));
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push_wait(2, None));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(matches!(h.join().unwrap(), Err(PushError::Closed(2))));
    }
}
