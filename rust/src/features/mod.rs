//! Feature Analyzer (paper §IV-C): expands the Roofline model into a
//! multi-dimensional analysis — for every key instruction pipeline (Tensor,
//! FMA, XU math pipes; Global/L2/Shared MIO) it derives *demand* and
//! *theoretical cycles* at GPU level and at the most-loaded-SM level
//! (Table IV), producing the fixed-width input vector of the Performance
//! Estimator MLP.

use crate::hw::GpuSpec;
use crate::kernels::Decomposition;
use crate::sched::TaskDistribution;

/// Model input width — must match `python/compile/model.py::FEATURE_DIM`
/// (checked against artifacts/manifest.json at runtime).
pub const FEATURE_DIM: usize = 32;

/// Table IV "Math" rows for one pipeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipeAgg {
    pub total_ops: f64,
    /// GPU-level theoretical cycles (Eq. 5): total ops over aggregate
    /// pipeline throughput.
    pub total_cycles: f64,
    pub max_sm_ops: f64,
    pub max_sm_cycles: f64,
}

/// Table IV "MIO" rows.
#[derive(Debug, Clone, Copy, Default)]
pub struct MioAgg {
    /// Total loaded bytes (loads sit on the critical path — §IV-C2).
    pub total_bytes: f64,
    pub cycles_dram: f64,
    pub cycles_l2: f64,
    pub max_sm_bytes: f64,
    pub max_sm_cycles_dram: f64,
    pub max_sm_cycles_l2: f64,
    pub max_sm_cycles_smem: f64,
}

/// The complete multi-level feature set for one kernel launch.
#[derive(Debug, Clone)]
pub struct FeatureSet {
    pub tensor: PipeAgg,
    pub fma: PipeAgg,
    pub xu: PipeAgg,
    pub mio: MioAgg,
    pub num_tasks: f64,
    pub max_tasks_per_sm: f64,
    /// max-SM critical cycles over mean-SM critical cycles (load imbalance).
    pub imbalance: f64,
    pub occupancy: f64,
    /// Wave count: tasks / (SMs x occupancy).
    pub waves: f64,
    /// The dominant single-pipeline roof in seconds — the "theoretical
    /// execution time" whose ratio to measured latency defines efficiency.
    /// Memory roof uses *compulsory* DRAM traffic (a valid lower bound).
    pub theory_sec: f64,
    /// The classic Roofline estimate with the naive memory term (summed
    /// per-task loads over DRAM bandwidth) — the paper's Roofline baseline,
    /// which overestimates latency on reuse-heavy kernels (§VI-C, H800).
    pub naive_roofline_sec: f64,
}

impl FeatureSet {
    /// Analyze a scheduled kernel on `gpu` — the bottom-up task -> SM -> GPU
    /// aggregation of §IV-C, computed in closed form over run-length task
    /// groups: one pass over SM × group counts (O(num_sms · num_groups)),
    /// no per-task walk and no scratch vectors. Per-SM sums replace
    /// repeated addition of a group's identical per-task demands with
    /// count · demand; every demand is an exactly-representable
    /// integer-valued f64, so the results are bit-identical to the
    /// element-wise reference (pinned by the equivalence property tests).
    pub fn analyze(decomp: &Decomposition, dist: &TaskDistribution, gpu: &GpuSpec) -> FeatureSet {
        let nsm = gpu.num_sms as f64;
        let groups = &decomp.task_groups;
        let dram_bpc = gpu.dram_bytes_per_cycle();
        let l2_bpc = gpu.l2_bytes_per_cycle();

        let mut total_tensor = 0.0f64;
        let mut total_fma = 0.0f64;
        let mut total_xu = 0.0f64;
        let mut total_bytes = 0.0f64;
        let mut max_tensor = 0.0f64;
        let mut max_fma = 0.0f64;
        let mut max_xu = 0.0f64;
        let mut max_bytes = 0.0f64;
        let mut max_smem = 0.0f64;
        let mut max_crit = 0.0f64;
        let mut crit_sum = 0.0f64;
        let mut busy_sms = 0usize;
        let mut max_tasks = 0u64;

        for j in 0..dist.num_sms() {
            let mut s_tensor = 0.0f64;
            let mut s_fma = 0.0f64;
            let mut s_xu = 0.0f64;
            let mut s_bytes = 0.0f64;
            let mut s_smem = 0.0f64;
            let mut n_tasks = 0u64;
            dist.visit_sm(j, |g, count| {
                let t = &groups[g].template;
                let c = count as f64;
                s_tensor += c * t.tensor_ops;
                s_fma += c * t.fma_ops;
                s_xu += c * t.xu_ops;
                s_bytes += c * t.bytes_load;
                s_smem += c * t.bytes_smem;
                n_tasks += count;
            });
            total_tensor += s_tensor;
            total_fma += s_fma;
            total_xu += s_xu;
            total_bytes += s_bytes;
            max_tensor = max_tensor.max(s_tensor);
            max_fma = max_fma.max(s_fma);
            max_xu = max_xu.max(s_xu);
            max_bytes = max_bytes.max(s_bytes);
            max_smem = max_smem.max(s_smem);
            max_tasks = max_tasks.max(n_tasks);
            // per-SM critical cycles: the max over pipeline roofs on this SM
            let crit = (s_tensor / gpu.tensor_ops_clk_sm)
                .max(s_fma / gpu.fma_ops_clk_sm)
                .max(s_xu / gpu.xu_ops_clk_sm)
                .max(s_bytes / (dram_bpc / nsm));
            max_crit = max_crit.max(crit);
            if crit > 0.0 {
                crit_sum += crit;
                busy_sms += 1;
            }
        }

        let tensor = PipeAgg {
            total_ops: total_tensor,
            total_cycles: total_tensor / (nsm * gpu.tensor_ops_clk_sm),
            max_sm_ops: max_tensor,
            max_sm_cycles: max_tensor / gpu.tensor_ops_clk_sm,
        };
        let fma = PipeAgg {
            total_ops: total_fma,
            total_cycles: total_fma / (nsm * gpu.fma_ops_clk_sm),
            max_sm_ops: max_fma,
            max_sm_cycles: max_fma / gpu.fma_ops_clk_sm,
        };
        let xu = PipeAgg {
            total_ops: total_xu,
            total_cycles: total_xu / (nsm * gpu.xu_ops_clk_sm),
            max_sm_ops: max_xu,
            max_sm_cycles: max_xu / gpu.xu_ops_clk_sm,
        };
        let mio = MioAgg {
            total_bytes,
            cycles_dram: total_bytes / dram_bpc,
            cycles_l2: total_bytes / l2_bpc,
            max_sm_bytes: max_bytes,
            // per-SM view uses fair-share slices of the chip-level paths
            max_sm_cycles_dram: max_bytes / (dram_bpc / nsm),
            max_sm_cycles_l2: max_bytes / (l2_bpc / nsm),
            max_sm_cycles_smem: max_smem / gpu.smem_bw_byte_clk_sm,
        };
        let mean_crit =
            if busy_sms == 0 { 0.0 } else { crit_sum / busy_sms as f64 };

        let occupancy = decomp.cta.occupancy(gpu) as f64;
        let num_tasks = decomp.num_tasks() as f64;
        let max_tasks = max_tasks as f64;

        let total_stores: f64 = decomp.group_sum(|t| t.bytes_store);
        let compute_roof = tensor.total_cycles.max(fma.total_cycles).max(xu.total_cycles);
        let theory_cycles = compute_roof.max(decomp.min_dram_bytes / dram_bpc);
        // classic roofline counts all traffic (loads + stores), unfiltered
        let naive_cycles = compute_roof.max((total_bytes + total_stores) / dram_bpc);

        FeatureSet {
            tensor,
            fma,
            xu,
            mio,
            num_tasks,
            max_tasks_per_sm: max_tasks,
            imbalance: if mean_crit > 0.0 { max_crit / mean_crit } else { 1.0 },
            occupancy,
            waves: num_tasks / (nsm * occupancy),
            theory_sec: theory_cycles * gpu.cycle_sec(),
            naive_roofline_sec: naive_cycles * gpu.cycle_sec(),
        }
    }

    /// Flatten into the MLP input layout (log1p-compressed demands/cycles +
    /// hardware descriptors). Standardization happens later with the
    /// training-set scaler.
    pub fn to_model_input(&self, gpu: &GpuSpec) -> [f32; FEATURE_DIM] {
        #[inline]
        fn l(v: f64) -> f32 {
            (v.max(0.0)).ln_1p() as f32
        }
        let mut x = [0f32; FEATURE_DIM];
        let pipes = [&self.tensor, &self.fma, &self.xu];
        for (p, agg) in pipes.iter().enumerate() {
            x[p * 4] = l(agg.total_ops);
            x[p * 4 + 1] = l(agg.total_cycles);
            x[p * 4 + 2] = l(agg.max_sm_ops);
            x[p * 4 + 3] = l(agg.max_sm_cycles);
        }
        x[12] = l(self.mio.total_bytes);
        x[13] = l(self.mio.cycles_dram);
        // the dominant roof in cycles: cleanly separates the launch-overhead
        // regime (tiny kernels) from the saturated regime — the Fig. 3
        // saturation axis made explicit (cycles_l2 is derivable from x[12]
        // and the L2-bandwidth descriptor, so this slot is better spent)
        x[14] = l(self.theory_sec * 1e9);
        x[15] = l(self.mio.max_sm_bytes);
        x[16] = l(self.mio.max_sm_cycles_dram);
        x[17] = l(self.mio.max_sm_cycles_l2);
        x[18] = l(self.mio.max_sm_cycles_smem);
        x[19] = l(self.num_tasks);
        x[20] = l(self.max_tasks_per_sm);
        x[21] = self.imbalance.min(16.0) as f32;
        x[22] = self.occupancy as f32;
        x[23] = l(self.waves);
        // hardware spec vector S (Table II), log-compressed
        x[24] = (gpu.num_sms as f64).ln() as f32;
        x[25] = gpu.sm_clock_mhz.ln() as f32;
        x[26] = gpu.dram_bw_gbs.ln() as f32;
        x[27] = gpu.l2_bw_gbs.ln() as f32;
        x[28] = gpu.tensor_ops_clk_sm.ln() as f32;
        x[29] = gpu.compute_mem_ratio().ln() as f32;
        x[30] = gpu.smem_kb_sm as f32 / 100.0;
        x[31] = gpu.l2_mb.ln() as f32;
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::{DType, KernelConfig};
    use crate::sched::schedule;

    fn features(cfg: &KernelConfig, gpu_name: &str) -> (FeatureSet, GpuSpec) {
        let gpu = gpu_by_name(gpu_name).unwrap();
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        (FeatureSet::analyze(&d, &dist, &gpu), gpu)
    }

    #[test]
    fn gemm_is_tensor_bound_on_h800() {
        let (f, gpu) = features(
            &KernelConfig::Gemm { m: 8192, n: 8192, k: 8192, dtype: DType::Bf16 },
            "H800",
        );
        // with the compulsory-traffic memory roof, big GEMM is tensor-bound
        let expect = f.tensor.total_cycles * gpu.cycle_sec();
        assert!((f.theory_sec - expect).abs() < 1e-12);
        // the naive roofline (summed loads) overestimates the roof on H800 —
        // the §VI-C failure mode of the Roofline baseline
        assert!(f.naive_roofline_sec > 1.5 * f.theory_sec);
    }

    #[test]
    fn small_gemm_is_memory_bound_on_h20() {
        // H20's tiny compute-to-memory ratio: same GEMM leans compute-bound
        // there vs memory-bound on H800 (the §VI-C roofline contrast).
        let cfg = KernelConfig::Gemm { m: 256, n: 8192, k: 8192, dtype: DType::Bf16 };
        let (f20, _) = features(&cfg, "H20");
        let (f800, _) = features(&cfg, "H800");
        let bound20 = f20.tensor.total_cycles / f20.mio.cycles_dram;
        let bound800 = f800.tensor.total_cycles / f800.mio.cycles_dram;
        assert!(bound20 > 2.0 * bound800);
    }

    #[test]
    fn rmsnorm_memory_bound_everywhere() {
        for name in ["A40", "A100", "H100"] {
            let (f, _) = features(&KernelConfig::RmsNorm { seq: 8192, dim: 8192 }, name);
            assert!(f.mio.cycles_dram > f.fma.total_cycles, "{name}");
            assert_eq!(f.tensor.total_ops, 0.0);
        }
    }

    #[test]
    fn totals_equal_decomposition_sums() {
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = KernelConfig::Gemm { m: 2048, n: 4096, k: 1024, dtype: DType::Bf16 };
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let f = FeatureSet::analyze(&d, &dist, &gpu);
        assert!((f.tensor.total_ops - d.total_tensor_ops()).abs() < 1.0);
        let loads: f64 = d.iter_tasks().map(|t| t.bytes_load).sum();
        assert!((f.mio.total_bytes - loads).abs() < 1.0);
    }

    #[test]
    fn causal_attention_shows_imbalance_under_rr() {
        let (f, _) = features(
            &KernelConfig::Attention {
                batch: vec![(4096, 4096)],
                nh: 4,
                nkv: 4,
                hd: 128,
                causal: true,
                fa3: false,
            },
            "A100",
        );
        assert!(f.imbalance > 1.02, "causal RR should be imbalanced: {}", f.imbalance);
    }

    #[test]
    fn minheap_less_imbalanced_than_rr() {
        let gpu = gpu_by_name("H100").unwrap();
        let mk = |fa3| KernelConfig::Attention {
            batch: vec![(8192, 8192)],
            nh: 8,
            nkv: 8,
            hd: 128,
            causal: true,
            fa3,
        };
        let d2 = mk(false).decompose(&gpu);
        let d3 = mk(true).decompose(&gpu);
        let f2 = FeatureSet::analyze(&d2, &schedule(&d2, &gpu), &gpu);
        let f3 = FeatureSet::analyze(&d3, &schedule(&d3, &gpu), &gpu);
        assert!(f3.imbalance <= f2.imbalance + 1e-9);
    }

    #[test]
    fn model_input_finite_and_wide() {
        let (f, gpu) = features(
            &KernelConfig::SiluMul { seq: 4096, dim: 13824 },
            "RTX PRO 6000 S",
        );
        let x = f.to_model_input(&gpu);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x.iter().filter(|v| **v != 0.0).count() > 15);
    }

    #[test]
    fn theory_time_scales_with_hardware() {
        // A GEMM roof should be much lower on H800 than on L20.
        let cfg = KernelConfig::Gemm { m: 8192, n: 8192, k: 8192, dtype: DType::Bf16 };
        let (fh, _) = features(&cfg, "H800");
        let (fl, _) = features(&cfg, "L20");
        assert!(fh.theory_sec * 4.0 < fl.theory_sec);
    }
}
