//! End-to-end evaluation of an inference trace (paper §V-D / §VI-D):
//! ground truth from the oracle testbed vs. the five predictors (SynPerf,
//! Roofline, Linear, Habitat, Neusight), all sharing the same RF
//! communication model so the comparison isolates kernel modeling.
//!
//! Kernel items route through the protocol-v1 request path
//! ([`crate::api::predict_batch_view_on`]): a trace launches the same
//! kernel shapes layer after layer (and decode step after decode step), so
//! the analytical half hits the engine's decomposition cache for every
//! repeat; the per-category MLP forwards are batched across the whole
//! trace. The answers carry provenance —
//! [`MethodTotals::degraded_kernels`] counts SynPerf kernel items that
//! fell back to the roofline (untrained category), so a degraded E2E
//! number is distinguishable from a real one.
//!
//! Evaluation is **two-pass deterministic-parallel**: pass 1 computes
//! every item's seed-dependent measurements (oracle sampling, comm
//! oracles/predictions) in parallel into an index-ordered buffer — each
//! item's values depend only on `(op, gpu, seed)`, never on its neighbors
//! — and pass 2 accumulates totals serially in stream order, exactly as
//! the single-threaded walk always did. Grand totals are therefore
//! bit-identical at every thread count.
//!
//! This is the reference evaluator the declarative Scenario API
//! ([`crate::scenario`]) is pinned against: `scenario::evaluate` walks the
//! same op stream with the same per-item seeds and must produce
//! bit-identical [`MethodTotals`] (see `tests/proptests.rs`).

use super::comm::{allreduce_oracle, sendrecv_oracle, CommModel};
use super::trace::{Op, TraceItem};
use crate::api::{self, FeatureView, Source};
use crate::baselines::linear::LinearModel;
use crate::dataset::Sample;
use crate::engine::{par, PredictionEngine};
use crate::hw::GpuSpec;
use crate::kernels::{KernelConfig, KernelKind};
use crate::mlp::Predictor;
use anyhow::Result;
use std::collections::HashMap;

/// Per-kernel-category trained models (one MLP per category, §IV-D). The
/// default (empty maps) is the documented degraded mode: SynPerf/Neusight
/// answer the theory roof, Linear falls back to the naive roofline.
#[derive(Default)]
pub struct ModelSet {
    pub synperf: HashMap<KernelKind, Predictor>,
    pub neusight: HashMap<KernelKind, Predictor>,
    pub linear: HashMap<KernelKind, LinearModel>,
}

/// The closed set of evaluated methods: ground truth plus the five
/// predictors every E2E table compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Actual,
    SynPerf,
    Roofline,
    Linear,
    Habitat,
    Neusight,
}

impl Method {
    pub const ALL: [Method; 6] = [
        Method::Actual,
        Method::SynPerf,
        Method::Roofline,
        Method::Linear,
        Method::Habitat,
        Method::Neusight,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Actual => "actual",
            Method::SynPerf => "synperf",
            Method::Roofline => "roofline",
            Method::Linear => "linear",
            Method::Habitat => "habitat",
            Method::Neusight => "neusight",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        Self::ALL.into_iter().find(|m| m.name() == s)
    }
}

/// E2E latency totals per method, seconds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodTotals {
    pub actual: f64,
    pub synperf: f64,
    pub roofline: f64,
    pub linear: f64,
    pub habitat: f64,
    pub neusight: f64,
    /// Kernel items whose SynPerf answer was the degraded roofline
    /// fallback (provenance `Source::Roofline`); 0 means every kernel item
    /// was answered by a trained MLP.
    pub degraded_kernels: usize,
}

impl MethodTotals {
    pub fn get(&self, m: Method) -> f64 {
        match m {
            Method::Actual => self.actual,
            Method::SynPerf => self.synperf,
            Method::Roofline => self.roofline,
            Method::Linear => self.linear,
            Method::Habitat => self.habitat,
            Method::Neusight => self.neusight,
        }
    }

    pub fn set(&mut self, m: Method, v: f64) {
        match m {
            Method::Actual => self.actual = v,
            Method::SynPerf => self.synperf = v,
            Method::Roofline => self.roofline = v,
            Method::Linear => self.linear = v,
            Method::Habitat => self.habitat = v,
            Method::Neusight => self.neusight = v,
        }
    }
}

/// Default host-side launch gap per kernel in the measured system
/// (framework overhead; part of ground truth, not modeled by any predictor
/// — §VI-D's "assume sequential kernel execution"). Scenario callers
/// override it per spec ([`crate::scenario::ScenarioSpec::host_gap_sec`]);
/// `eval_trace` takes it as a parameter so ground truth and report agree.
pub const HOST_GAP_SEC: f64 = 0.8e-6;

/// Minimum op items per prospective worker before the evaluators' pass 1
/// fans out. Items are heavyweight (a kernel item runs three seeded
/// oracle simulations), so the grain is small — but a handful-of-item
/// scenario on a many-core host should run serially rather than pay a
/// scoped-thread spawn per core.
pub(crate) const EVAL_PAR_GRAIN: usize = 4;

/// One op's seed-dependent measurements — the output of the parallel
/// per-item pass both evaluators share. Kernel items carry the full
/// profiled [`Sample`]; comm items carry the ground-truth latency and the
/// shared RF prediction.
pub(crate) enum ItemEval {
    Kernel(Sample),
    Comm { actual: f64, pred: f64 },
}

/// Evaluate one op's seed-dependent half. Pure in `(op, gpu, tp, op_seed)`
/// — the engine cache only memoizes pure analyses — so fanning items out
/// over threads cannot change a single bit of any item's result.
pub(crate) fn eval_op(
    engine: &PredictionEngine,
    op: &Op,
    gpu: &GpuSpec,
    tp: u32,
    comm: &CommModel,
    op_seed: u64,
) -> ItemEval {
    match op {
        Op::Kernel(cfg) => ItemEval::Kernel(engine.make_sample(cfg, gpu, op_seed)),
        Op::AllReduce { bytes } => ItemEval::Comm {
            actual: allreduce_oracle(*bytes, tp, gpu, op_seed),
            pred: comm.predict_allreduce(*bytes, tp, gpu),
        },
        Op::SendRecv { bytes } => ItemEval::Comm {
            actual: sendrecv_oracle(*bytes, gpu, op_seed),
            pred: comm.predict_sendrecv(*bytes, gpu),
        },
    }
}

#[allow(clippy::too_many_arguments)]
pub fn eval_trace(
    trace: &[TraceItem],
    gpu: &GpuSpec,
    tp: u32,
    models: &ModelSet,
    comm: &CommModel,
    seed: u64,
    host_gap_sec: f64,
    threads: usize,
) -> Result<MethodTotals> {
    let engine = PredictionEngine::global();
    // pass 1 — parallel per-item measurements into an index-ordered
    // buffer (small traces stay serial: see EVAL_PAR_GRAIN)
    let threads = threads.min(trace.len().div_ceil(EVAL_PAR_GRAIN)).max(1);
    let evals: Vec<ItemEval> = par::par_map(trace, threads, |i, item| {
        eval_op(engine, &item.op, gpu, tp, comm, seed.wrapping_add(i as u64 * 0x9E37))
    });

    // pass 2 — serial stream-order accumulation, unchanged from the
    // single-threaded reference (bit-identical at every thread count)
    let mut t = MethodTotals::default();
    // kernel launches accumulated for one batched routing pass per method
    let mut kernel_cfgs: Vec<&KernelConfig> = Vec::new();
    let mut kernel_counts: Vec<f64> = Vec::new();
    for (item, ev) in trace.iter().zip(&evals) {
        match ev {
            ItemEval::Kernel(s) => {
                t.actual += item.count * (s.latency_sec + host_gap_sec);
                t.roofline += item.count * s.roofline_sec;
                t.habitat += item.count * s.habitat_sec;
                if let Some(lm) = models.linear.get(&s.kind) {
                    t.linear += item.count * lm.predict(s);
                } else {
                    t.linear += item.count * s.roofline_sec; // no model: fall back
                }
                let Op::Kernel(cfg) = &item.op else {
                    unreachable!("pass-1 evals align with trace items")
                };
                kernel_cfgs.push(cfg);
                kernel_counts.push(item.count);
            }
            ItemEval::Comm { actual, pred } => {
                t.actual += item.count * actual;
                for p in [
                    &mut t.synperf,
                    &mut t.roofline,
                    &mut t.linear,
                    &mut t.habitat,
                    &mut t.neusight,
                ] {
                    *p += item.count * pred;
                }
            }
        }
    }

    // the one request path: per-category batched MLP routing with
    // provenance, once per feature view (SynPerf, Neusight baseline)
    let syn =
        api::predict_batch_view_on(&models.synperf, FeatureView::SynPerf, gpu, &kernel_cfgs, threads);
    let neu = api::predict_batch_view_on(
        &models.neusight,
        FeatureView::Neusight,
        gpu,
        &kernel_cfgs,
        threads,
    );
    for ((sp, np), count) in syn.iter().zip(&neu).zip(&kernel_counts) {
        t.synperf += count * sp.latency_sec;
        t.neusight += count * np.latency_sec;
        if sp.provenance.source == Source::Roofline {
            t.degraded_kernels += 1;
        }
    }
    Ok(t)
}
