//! End-to-end evaluation of an inference trace (paper §V-D / §VI-D):
//! ground truth from the oracle testbed vs. the five predictors (SynPerf,
//! Roofline, Linear, Habitat, Neusight), all sharing the same RF
//! communication model so the comparison isolates kernel modeling.
//!
//! Kernel items route through the protocol-v1 request path
//! ([`crate::api::predict_batch_view`]): a trace launches the same kernel
//! shapes layer after layer (and decode step after decode step), so the
//! analytical half hits the engine's decomposition cache for every repeat;
//! the per-category MLP forwards are batched across the whole trace. The
//! answers carry provenance — [`MethodTotals::degraded_kernels`] counts
//! SynPerf kernel items that fell back to the roofline (untrained
//! category), so a degraded E2E number is distinguishable from a real one.

use super::comm::{allreduce_oracle, sendrecv_oracle, CommModel};
use super::trace::{Op, TraceItem};
use crate::api::{self, FeatureView, Source};
use crate::baselines::linear::LinearModel;
use crate::engine::PredictionEngine;
use crate::hw::GpuSpec;
use crate::kernels::{KernelConfig, KernelKind};
use crate::mlp::Predictor;
use anyhow::Result;
use std::collections::HashMap;

/// Per-kernel-category trained models (one MLP per category, §IV-D).
pub struct ModelSet {
    pub synperf: HashMap<KernelKind, Predictor>,
    pub neusight: HashMap<KernelKind, Predictor>,
    pub linear: HashMap<KernelKind, LinearModel>,
}

/// E2E latency totals per method, seconds.
#[derive(Debug, Clone, Default)]
pub struct MethodTotals {
    pub actual: f64,
    pub synperf: f64,
    pub roofline: f64,
    pub linear: f64,
    pub habitat: f64,
    pub neusight: f64,
    /// Kernel items whose SynPerf answer was the degraded roofline
    /// fallback (provenance `Source::Roofline`); 0 means every kernel item
    /// was answered by a trained MLP.
    pub degraded_kernels: usize,
}

/// Host-side launch gap per kernel in the measured system (framework
/// overhead; part of ground truth, not modeled by any predictor — §VI-D's
/// "assume sequential kernel execution").
pub const HOST_GAP_SEC: f64 = 0.8e-6;

pub fn eval_trace(
    trace: &[TraceItem],
    gpu: &GpuSpec,
    tp: u32,
    models: &ModelSet,
    comm: &CommModel,
    seed: u64,
) -> Result<MethodTotals> {
    let engine = PredictionEngine::global();
    let mut t = MethodTotals::default();
    // kernel launches accumulated for one batched routing pass per method
    let mut kernel_reqs: Vec<(KernelConfig, GpuSpec)> = Vec::new();
    let mut kernel_counts: Vec<f64> = Vec::new();

    for (i, item) in trace.iter().enumerate() {
        let op_seed = seed.wrapping_add(i as u64 * 0x9E37);
        match &item.op {
            Op::Kernel(cfg) => {
                let s = engine.make_sample(cfg, gpu, op_seed);
                t.actual += item.count * (s.latency_sec + HOST_GAP_SEC);
                t.roofline += item.count * s.roofline_sec;
                t.habitat += item.count * s.habitat_sec;
                if let Some(lm) = models.linear.get(&s.kind) {
                    t.linear += item.count * lm.predict(&s);
                } else {
                    t.linear += item.count * s.roofline_sec; // no model: fall back
                }
                kernel_reqs.push((cfg.clone(), gpu.clone()));
                kernel_counts.push(item.count);
            }
            Op::AllReduce { bytes } => {
                let actual = allreduce_oracle(*bytes, tp, gpu, op_seed);
                let pred = comm.predict_allreduce(*bytes, tp, gpu);
                t.actual += item.count * actual;
                for p in [
                    &mut t.synperf,
                    &mut t.roofline,
                    &mut t.linear,
                    &mut t.habitat,
                    &mut t.neusight,
                ] {
                    *p += item.count * pred;
                }
            }
            Op::SendRecv { bytes } => {
                let actual = sendrecv_oracle(*bytes, gpu, op_seed);
                let pred = comm.predict_sendrecv(*bytes, gpu);
                t.actual += item.count * actual;
                for p in [
                    &mut t.synperf,
                    &mut t.roofline,
                    &mut t.linear,
                    &mut t.habitat,
                    &mut t.neusight,
                ] {
                    *p += item.count * pred;
                }
            }
        }
    }

    // the one request path: per-category batched MLP routing with
    // provenance, once per feature view (SynPerf, Neusight baseline)
    let syn = api::predict_batch_view(&models.synperf, FeatureView::SynPerf, &kernel_reqs);
    let neu = api::predict_batch_view(&models.neusight, FeatureView::Neusight, &kernel_reqs);
    for ((sp, np), count) in syn.iter().zip(&neu).zip(&kernel_counts) {
        t.synperf += count * sp.latency_sec;
        t.neusight += count * np.latency_sec;
        if sp.provenance.source == Source::Roofline {
            t.degraded_kernels += 1;
        }
    }
    Ok(t)
}

/// Runtime breakdown of a trace by kernel category (Table I).
pub fn breakdown(trace: &[TraceItem], gpu: &GpuSpec, tp: u32, seed: u64) -> Vec<(String, f64)> {
    let engine = PredictionEngine::global();
    let mut buckets: HashMap<&'static str, f64> = HashMap::new();
    for (i, item) in trace.iter().enumerate() {
        let op_seed = seed.wrapping_add(i as u64 * 0x9E37);
        let (name, secs): (&'static str, f64) = match &item.op {
            Op::Kernel(cfg) => {
                let s = engine.make_sample(cfg, gpu, op_seed);
                let bucket = match cfg.kind() {
                    KernelKind::Gemm | KernelKind::ScaledMm => "GEMM",
                    KernelKind::Attention => "Attention",
                    KernelKind::RmsNorm => "RMSNorm",
                    KernelKind::SiluMul => "SiLU&Mul",
                    KernelKind::FusedMoe => "FusedMoE",
                };
                *buckets.entry("Other").or_default() += item.count * HOST_GAP_SEC;
                (bucket, s.latency_sec)
            }
            Op::AllReduce { bytes } => ("All-Reduce", allreduce_oracle(*bytes, tp, gpu, op_seed)),
            Op::SendRecv { bytes } => ("Other", sendrecv_oracle(*bytes, gpu, op_seed)),
        };
        *buckets.entry(name).or_default() += item.count * secs;
    }
    let total: f64 = buckets.values().sum();
    let mut rows: Vec<(String, f64)> =
        buckets.into_iter().map(|(k, v)| (k.to_string(), 100.0 * v / total)).collect();
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    rows
}
