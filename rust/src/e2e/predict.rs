//! End-to-end evaluation of an inference trace (paper §V-D / §VI-D):
//! ground truth from the oracle testbed vs. the five predictors (SynPerf,
//! Roofline, Linear, Habitat, Neusight), all sharing the same RF
//! communication model so the comparison isolates kernel modeling.
//!
//! Kernel items route through the shared [`PredictionEngine`]: a trace
//! launches the same kernel shapes layer after layer (and decode step after
//! decode step), so the analytical half of `make_sample` hits the engine's
//! decomposition cache for every repeat; the per-category MLP forwards are
//! batched across the whole trace.

use super::comm::{allreduce_oracle, sendrecv_oracle, CommModel};
use super::trace::{Op, TraceItem};
use crate::baselines::linear::LinearModel;
use crate::engine::PredictionEngine;
use crate::features::FEATURE_DIM;
use crate::hw::GpuSpec;
use crate::kernels::KernelKind;
use crate::mlp::Predictor;
use anyhow::Result;
use std::collections::HashMap;

/// Per-kernel-category trained models (one MLP per category, §IV-D).
pub struct ModelSet {
    pub synperf: HashMap<KernelKind, Predictor>,
    pub neusight: HashMap<KernelKind, Predictor>,
    pub linear: HashMap<KernelKind, LinearModel>,
}

/// E2E latency totals per method, seconds.
#[derive(Debug, Clone, Default)]
pub struct MethodTotals {
    pub actual: f64,
    pub synperf: f64,
    pub roofline: f64,
    pub linear: f64,
    pub habitat: f64,
    pub neusight: f64,
}

/// Host-side launch gap per kernel in the measured system (framework
/// overhead; part of ground truth, not modeled by any predictor — §VI-D's
/// "assume sequential kernel execution").
pub const HOST_GAP_SEC: f64 = 0.8e-6;

pub fn eval_trace(
    trace: &[TraceItem],
    gpu: &GpuSpec,
    tp: u32,
    models: &ModelSet,
    comm: &CommModel,
    seed: u64,
) -> Result<MethodTotals> {
    let engine = PredictionEngine::global();
    let mut t = MethodTotals::default();
    // batched MLP inputs per kernel category
    let mut syn_in: HashMap<KernelKind, Vec<([f32; FEATURE_DIM], f64, f64)>> = HashMap::new();
    let mut alt_in: HashMap<KernelKind, Vec<([f32; FEATURE_DIM], f64, f64)>> = HashMap::new();

    for (i, item) in trace.iter().enumerate() {
        let op_seed = seed.wrapping_add(i as u64 * 0x9E37);
        match &item.op {
            Op::Kernel(cfg) => {
                let s = engine.make_sample(cfg, gpu, op_seed);
                t.actual += item.count * (s.latency_sec + HOST_GAP_SEC);
                t.roofline += item.count * s.roofline_sec;
                t.habitat += item.count * s.habitat_sec;
                if let Some(lm) = models.linear.get(&s.kind) {
                    t.linear += item.count * lm.predict(&s);
                } else {
                    t.linear += item.count * s.roofline_sec; // no model: fall back
                }
                syn_in.entry(s.kind).or_default().push((s.x, s.theory_sec, item.count));
                alt_in.entry(s.kind).or_default().push((s.x_alt, s.alt_theory_sec, item.count));
            }
            Op::AllReduce { bytes } => {
                let actual = allreduce_oracle(*bytes, tp, gpu, op_seed);
                let pred = comm.predict_allreduce(*bytes, tp, gpu);
                t.actual += item.count * actual;
                for p in [
                    &mut t.synperf,
                    &mut t.roofline,
                    &mut t.linear,
                    &mut t.habitat,
                    &mut t.neusight,
                ] {
                    *p += item.count * pred;
                }
            }
            Op::SendRecv { bytes } => {
                let actual = sendrecv_oracle(*bytes, gpu, op_seed);
                let pred = comm.predict_sendrecv(*bytes, gpu);
                t.actual += item.count * actual;
                for p in [
                    &mut t.synperf,
                    &mut t.roofline,
                    &mut t.linear,
                    &mut t.habitat,
                    &mut t.neusight,
                ] {
                    *p += item.count * pred;
                }
            }
        }
    }

    // batched MLP predictions, one forward per (method, kernel category)
    for (kind, rows) in &syn_in {
        let xs: Vec<[f32; FEATURE_DIM]> = rows.iter().map(|r| r.0).collect();
        let eff = PredictionEngine::predict_eff_grouped(&models.synperf, *kind, &xs)?;
        for ((_, theory, count), e) in rows.iter().zip(eff) {
            t.synperf += count * theory / e;
        }
    }
    for (kind, rows) in &alt_in {
        let xs: Vec<[f32; FEATURE_DIM]> = rows.iter().map(|r| r.0).collect();
        let eff = PredictionEngine::predict_eff_grouped(&models.neusight, *kind, &xs)?;
        for ((_, theory, count), e) in rows.iter().zip(eff) {
            t.neusight += count * theory / e;
        }
    }
    Ok(t)
}

/// Runtime breakdown of a trace by kernel category (Table I).
pub fn breakdown(trace: &[TraceItem], gpu: &GpuSpec, tp: u32, seed: u64) -> Vec<(String, f64)> {
    let engine = PredictionEngine::global();
    let mut buckets: HashMap<&'static str, f64> = HashMap::new();
    for (i, item) in trace.iter().enumerate() {
        let op_seed = seed.wrapping_add(i as u64 * 0x9E37);
        let (name, secs): (&'static str, f64) = match &item.op {
            Op::Kernel(cfg) => {
                let s = engine.make_sample(cfg, gpu, op_seed);
                let bucket = match cfg.kind() {
                    KernelKind::Gemm | KernelKind::ScaledMm => "GEMM",
                    KernelKind::Attention => "Attention",
                    KernelKind::RmsNorm => "RMSNorm",
                    KernelKind::SiluMul => "SiLU&Mul",
                    KernelKind::FusedMoe => "FusedMoE",
                };
                *buckets.entry("Other").or_default() += item.count * HOST_GAP_SEC;
                (bucket, s.latency_sec)
            }
            Op::AllReduce { bytes } => ("All-Reduce", allreduce_oracle(*bytes, tp, gpu, op_seed)),
            Op::SendRecv { bytes } => ("Other", sendrecv_oracle(*bytes, gpu, op_seed)),
        };
        *buckets.entry(name).or_default() += item.count * secs;
    }
    let total: f64 = buckets.values().sum();
    let mut rows: Vec<(String, f64)> =
        buckets.into_iter().map(|(k, v)| (k.to_string(), 100.0 * v / total)).collect();
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    rows
}
