//! Workload Generator (paper §V-D): turns a model config + request batch +
//! parallelism into the kernel invocation sequence a serving framework
//! (SGLang/vLLM) would launch — prefill pass plus autoregressive decode,
//! with TP All-Reduce and PP Send/Recv communication ops.
//!
//! Decode is evaluated at four KV-length checkpoints (quartile midpoints of
//! the generation) and integrated — both ground truth and every predictor
//! consume the same trace, so the comparison stays exact while avoiding
//! thousands of near-identical per-step evaluations.

use super::llm::LlmConfig;
use super::workload::Request;
use crate::kernels::{DType, KernelConfig};

#[derive(Debug, Clone)]
pub enum Op {
    Kernel(KernelConfig),
    AllReduce { bytes: f64 },
    SendRecv { bytes: f64 },
}

/// One trace entry with a repetition count (layers x integrated steps).
#[derive(Debug, Clone)]
pub struct TraceItem {
    pub op: Op,
    pub count: f64,
}

fn layer_ops(
    llm: &LlmConfig,
    tp: u32,
    m_tokens: u32,
    attn_batch: Vec<(u32, u32)>,
    count: f64,
    out: &mut Vec<TraceItem>,
) {
    let h = llm.hidden;
    let nh_t = (llm.heads / tp).max(1);
    let nkv_t = (llm.kv_heads / tp).max(1);
    let inter_t = (llm.intermediate / tp).max(1);
    let hd = llm.head_dim;
    let push = |out: &mut Vec<TraceItem>, op: Op| out.push(TraceItem { op, count });

    push(out, Op::Kernel(KernelConfig::RmsNorm { seq: m_tokens, dim: h }));
    push(
        out,
        Op::Kernel(KernelConfig::Gemm {
            m: m_tokens,
            n: (nh_t + 2 * nkv_t) * hd,
            k: h,
            dtype: DType::Bf16,
        }),
    );
    push(
        out,
        Op::Kernel(KernelConfig::Attention {
            batch: attn_batch,
            nh: nh_t,
            nkv: nkv_t,
            hd,
            causal: true,
            fa3: false, // resolved per-GPU by dataset::finalize_for_gpu
        }),
    );
    push(
        out,
        Op::Kernel(KernelConfig::Gemm { m: m_tokens, n: h, k: nh_t * hd, dtype: DType::Bf16 }),
    );
    if tp > 1 {
        push(out, Op::AllReduce { bytes: m_tokens as f64 * h as f64 * 2.0 });
    }
    push(out, Op::Kernel(KernelConfig::RmsNorm { seq: m_tokens, dim: h }));
    push(
        out,
        Op::Kernel(KernelConfig::Gemm { m: m_tokens, n: 2 * inter_t, k: h, dtype: DType::Bf16 }),
    );
    push(out, Op::Kernel(KernelConfig::SiluMul { seq: m_tokens, dim: inter_t }));
    push(
        out,
        Op::Kernel(KernelConfig::Gemm { m: m_tokens, n: h, k: inter_t, dtype: DType::Bf16 }),
    );
    if tp > 1 {
        push(out, Op::AllReduce { bytes: m_tokens as f64 * h as f64 * 2.0 });
    }
}

/// Build the full inference trace for one batch.
pub fn build_trace(llm: &LlmConfig, tp: u32, pp: u32, reqs: &[Request]) -> Vec<TraceItem> {
    let (mut prefill, decode) = build_phase_traces(llm, tp, pp, reqs);
    prefill.extend(decode);
    prefill
}

/// Build the prefill trace alone: one forward pass over the whole prompt
/// batch plus the LM head on each request's last token. This is also the
/// cluster simulator's prefill-step trace (Scenario v2), so it is public
/// and `build_phase_traces` delegates to it — the two surfaces cannot
/// drift.
pub fn build_prefill_trace(llm: &LlmConfig, tp: u32, pp: u32, reqs: &[Request]) -> Vec<TraceItem> {
    assert!(!reqs.is_empty());
    let mut out = Vec::new();
    let layers = llm.layers as f64;
    let m_prefill: u32 = reqs.iter().map(|r| r.input_len).sum();
    let attn_prefill: Vec<(u32, u32)> =
        reqs.iter().map(|r| (r.input_len, r.input_len)).collect();
    layer_ops(llm, tp, m_prefill, attn_prefill, layers, &mut out);
    if pp > 1 {
        out.push(TraceItem {
            op: Op::SendRecv { bytes: m_prefill as f64 * llm.hidden as f64 * 2.0 },
            count: (pp - 1) as f64,
        });
    }
    // LM head on the last token of each request
    let bs = reqs.len() as u32;
    out.push(TraceItem {
        op: Op::Kernel(KernelConfig::Gemm {
            m: bs,
            n: (llm.vocab / tp).max(1),
            k: llm.hidden,
            dtype: DType::Bf16,
        }),
        count: 1.0,
    });
    out
}

/// One continuous-batching decode step (Scenario v2): every running
/// request appends a single token against its current KV length, so the
/// attention batch is `[(1, kv)]` per request in the given order, followed
/// by the LM head over the step's batch.
pub fn build_decode_step_trace(
    llm: &LlmConfig,
    tp: u32,
    pp: u32,
    kv_lens: &[u32],
) -> Vec<TraceItem> {
    assert!(!kv_lens.is_empty());
    let mut out = Vec::new();
    let layers = llm.layers as f64;
    let m_dec = kv_lens.len() as u32;
    let attn: Vec<(u32, u32)> = kv_lens.iter().map(|&kv| (1u32, kv.max(1))).collect();
    layer_ops(llm, tp, m_dec, attn, layers, &mut out);
    if pp > 1 {
        out.push(TraceItem {
            op: Op::SendRecv { bytes: m_dec as f64 * llm.hidden as f64 * 2.0 },
            count: (pp - 1) as f64,
        });
    }
    out.push(TraceItem {
        op: Op::Kernel(KernelConfig::Gemm {
            m: m_dec,
            n: (llm.vocab / tp).max(1),
            k: llm.hidden,
            dtype: DType::Bf16,
        }),
        count: 1.0,
    });
    out
}

/// Build the prefill and decode traces separately (Table I reports the
/// runtime breakdown per phase).
pub fn build_phase_traces(
    llm: &LlmConfig,
    tp: u32,
    pp: u32,
    reqs: &[Request],
) -> (Vec<TraceItem>, Vec<TraceItem>) {
    assert!(!reqs.is_empty());
    let prefill_trace = build_prefill_trace(llm, tp, pp, reqs);
    let mut out = Vec::new();
    let layers = llm.layers as f64;

    // ---- decode: four quartile-midpoint checkpoints ----------------------
    let max_out = reqs.iter().map(|r| r.output_len).max().unwrap_or(1);
    let seg = (max_out as f64 / 4.0).max(1.0);
    for q in 0..4 {
        let step = ((q as f64 + 0.5) * seg) as u32;
        let active: Vec<&Request> = reqs.iter().filter(|r| r.output_len > step).collect();
        if active.is_empty() {
            continue;
        }
        // steps represented by this checkpoint = requests still active
        // integrated over the segment
        let steps_weight: f64 = reqs
            .iter()
            .map(|r| {
                let lo = (q as f64) * seg;
                let hi = ((q + 1) as f64) * seg;
                (r.output_len as f64).min(hi).max(lo) - lo
            })
            .sum::<f64>()
            / reqs.len() as f64
            * (reqs.len() as f64 / active.len().max(1) as f64).min(4.0);
        if steps_weight <= 0.0 {
            continue;
        }
        let m_dec = active.len() as u32;
        let attn_dec: Vec<(u32, u32)> =
            active.iter().map(|r| (1u32, r.input_len + step.min(r.output_len))).collect();
        layer_ops(llm, tp, m_dec, attn_dec, layers * steps_weight, &mut out);
        if pp > 1 {
            out.push(TraceItem {
                op: Op::SendRecv { bytes: m_dec as f64 * llm.hidden as f64 * 2.0 },
                count: (pp - 1) as f64 * steps_weight,
            });
        }
        out.push(TraceItem {
            op: Op::Kernel(KernelConfig::Gemm {
                m: m_dec,
                n: (llm.vocab / tp).max(1),
                k: llm.hidden,
                dtype: DType::Bf16,
            }),
            count: steps_weight,
        });
    }
    (prefill_trace, out)
}

/// Total kernel-launch count of a trace (for host-gap accounting).
pub fn launch_count(trace: &[TraceItem]) -> f64 {
    trace
        .iter()
        .filter(|t| matches!(t.op, Op::Kernel(_)))
        .map(|t| t.count)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::e2e::llm;

    fn reqs() -> Vec<Request> {
        vec![
            Request { input_len: 1000, output_len: 200 },
            Request { input_len: 2000, output_len: 100 },
        ]
    }

    fn model(name: &str) -> crate::e2e::llm::LlmConfig {
        llm::llm_by_name(name).unwrap()
    }

    #[test]
    fn trace_has_all_categories() {
        let t = build_trace(&model("Qwen2.5-14B"), 4, 1, &reqs());
        let mut has_gemm = false;
        let mut has_attn = false;
        let mut has_norm = false;
        let mut has_silu = false;
        let mut has_ar = false;
        for item in &t {
            match &item.op {
                Op::Kernel(KernelConfig::Gemm { .. }) => has_gemm = true,
                Op::Kernel(KernelConfig::Attention { .. }) => has_attn = true,
                Op::Kernel(KernelConfig::RmsNorm { .. }) => has_norm = true,
                Op::Kernel(KernelConfig::SiluMul { .. }) => has_silu = true,
                Op::AllReduce { .. } => has_ar = true,
                _ => {}
            }
        }
        assert!(has_gemm && has_attn && has_norm && has_silu && has_ar);
    }

    #[test]
    fn tp1_has_no_collectives() {
        let t = build_trace(&model("Qwen2.5-14B"), 1, 1, &reqs());
        assert!(!t.iter().any(|i| matches!(i.op, Op::AllReduce { .. } | Op::SendRecv { .. })));
    }

    #[test]
    fn pp_adds_sendrecv() {
        let t = build_trace(&model("Llama3.1-70B"), 4, 2, &reqs());
        assert!(t.iter().any(|i| matches!(i.op, Op::SendRecv { .. })));
    }

    #[test]
    fn tp_shrinks_gemm_width() {
        let t1 = build_trace(&model("Qwen3-32B"), 1, 1, &reqs());
        let t4 = build_trace(&model("Qwen3-32B"), 4, 1, &reqs());
        let max_n = |t: &[TraceItem]| {
            t.iter()
                .filter_map(|i| match &i.op {
                    Op::Kernel(KernelConfig::Gemm { n, .. }) => Some(*n),
                    _ => None,
                })
                .max()
                .unwrap()
        };
        assert!(max_n(&t4) < max_n(&t1));
    }

    #[test]
    fn decode_kv_grows_with_checkpoints() {
        let t = build_trace(&model("Qwen2.5-14B"), 1, 1, &reqs());
        let kvs: Vec<u32> = t
            .iter()
            .filter_map(|i| match &i.op {
                Op::Kernel(KernelConfig::Attention { batch, .. }) if batch[0].0 == 1 => {
                    Some(batch[0].1)
                }
                _ => None,
            })
            .collect();
        assert!(kvs.len() >= 2);
        assert!(kvs.windows(2).all(|w| w[0] <= w[1]), "{kvs:?}");
    }

    #[test]
    fn decode_step_trace_is_one_token_per_request() {
        let t = build_decode_step_trace(&model("Qwen2.5-14B"), 2, 2, &[100, 350, 7]);
        let attn = t
            .iter()
            .find_map(|i| match &i.op {
                Op::Kernel(KernelConfig::Attention { batch, .. }) => Some(batch.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!(attn, vec![(1, 100), (1, 350), (1, 7)]);
        // LM head covers the step batch; pp=2 adds a send/recv
        assert!(t.iter().any(|i| matches!(
            &i.op,
            Op::Kernel(KernelConfig::Gemm { m: 3, .. })
        )));
        assert!(t.iter().any(|i| matches!(i.op, Op::SendRecv { .. })));
    }

    #[test]
    fn launch_count_positive() {
        let t = build_trace(&model("Qwen2.5-14B"), 2, 1, &reqs());
        assert!(launch_count(&t) > 100.0);
    }
}
