//! LLM architecture registry for the end-to-end evaluation (paper §VI-D).
//! Values from the public HuggingFace model configs.
//!
//! Models are looked up **by name** through [`llm_by_name`] (mirroring
//! [`crate::hw::gpu_by_name`]); [`registry`] enumerates every known config.
//! There are no per-model constructors — adding a model is one new
//! [`LlmConfig`] row, immediately visible to the Scenario API, the CLI and
//! the experiments.

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LlmConfig {
    pub name: &'static str,
    pub hidden: u32,
    pub layers: u32,
    pub heads: u32,
    pub kv_heads: u32,
    pub head_dim: u32,
    pub intermediate: u32,
    pub vocab: u32,
}

impl LlmConfig {
    pub fn params_approx(&self) -> f64 {
        let h = self.hidden as f64;
        let per_layer = h * (self.heads + 2 * self.kv_heads) as f64 * self.head_dim as f64
            + h * (self.heads * self.head_dim) as f64
            + 3.0 * h * self.intermediate as f64;
        per_layer * self.layers as f64 + 2.0 * h * self.vocab as f64
    }
}

/// The model database: the paper's four evaluation models (Qwen2.5-14B,
/// Qwen2.5-32B of Table I, Qwen3-32B, Llama3.1-70B) plus Llama3.1-8B.
const REGISTRY: [LlmConfig; 5] = [
    LlmConfig {
        name: "Qwen2.5-14B",
        hidden: 5120,
        layers: 48,
        heads: 40,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 13_824,
        vocab: 152_064,
    },
    LlmConfig {
        name: "Qwen2.5-32B",
        hidden: 5120,
        layers: 64,
        heads: 40,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 27_648,
        vocab: 152_064,
    },
    LlmConfig {
        name: "Qwen3-32B",
        hidden: 5120,
        layers: 64,
        heads: 64,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 25_600,
        vocab: 151_936,
    },
    LlmConfig {
        name: "Llama3.1-70B",
        hidden: 8192,
        layers: 80,
        heads: 64,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 28_672,
        vocab: 128_256,
    },
    LlmConfig {
        name: "Llama3.1-8B",
        hidden: 4096,
        layers: 32,
        heads: 32,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 14_336,
        vocab: 128_256,
    },
];

/// Every registered model config, in registry order.
pub fn registry() -> &'static [LlmConfig] {
    &REGISTRY
}

/// Case/punctuation-insensitive model lookup ("qwen2.5-14b" ==
/// "Qwen2.5-14B" == "qwen2_5_14b").
pub fn llm_by_name(name: &str) -> Option<LlmConfig> {
    let norm = |s: &str| s.to_lowercase().replace(['-', '.', '_'], "");
    let n = norm(name);
    REGISTRY.iter().find(|cfg| norm(cfg.name) == n).cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_counts_roughly_match_names() {
        let billions = |name: &str| llm_by_name(name).unwrap().params_approx() / 1e9;
        assert!((billions("Qwen2.5-14B") - 14.0).abs() < 3.0);
        assert!((billions("Qwen3-32B") - 32.0).abs() < 6.0);
        assert!((billions("Llama3.1-70B") - 70.0).abs() < 10.0);
        assert!((billions("Llama3.1-8B") - 8.0).abs() < 2.0);
    }

    #[test]
    fn lookup_by_name() {
        assert!(llm_by_name("qwen2.5-14b").is_some());
        assert!(llm_by_name("Llama3.1-70B").is_some());
        assert!(llm_by_name("llama3_1_8b").is_some());
        assert!(llm_by_name("gpt-x").is_none());
    }

    #[test]
    fn registry_is_open_and_consistent() {
        assert!(registry().len() >= 5, "the registry must stay open to new configs");
        for cfg in registry() {
            assert_eq!(llm_by_name(cfg.name).as_ref(), Some(cfg), "{}", cfg.name);
            assert!(cfg.heads % cfg.kv_heads == 0, "{}: GQA group must divide", cfg.name);
            assert!(cfg.layers >= 2 && cfg.hidden >= 1024, "{}", cfg.name);
        }
    }
}
