//! LLM architecture configurations for the end-to-end evaluation (paper
//! §VI-D): Qwen2.5-14B, Qwen2.5-32B (Table I), Qwen3-32B, Llama3.1-70B.
//! Values from the public HuggingFace model configs.

#[derive(Debug, Clone)]
pub struct LlmConfig {
    pub name: &'static str,
    pub hidden: u32,
    pub layers: u32,
    pub heads: u32,
    pub kv_heads: u32,
    pub head_dim: u32,
    pub intermediate: u32,
    pub vocab: u32,
}

impl LlmConfig {
    pub fn params_approx(&self) -> f64 {
        let h = self.hidden as f64;
        let per_layer = h * (self.heads + 2 * self.kv_heads) as f64 * self.head_dim as f64
            + h * (self.heads * self.head_dim) as f64
            + 3.0 * h * self.intermediate as f64;
        per_layer * self.layers as f64 + 2.0 * h * self.vocab as f64
    }
}

pub fn qwen2_5_14b() -> LlmConfig {
    LlmConfig {
        name: "Qwen2.5-14B",
        hidden: 5120,
        layers: 48,
        heads: 40,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 13824,
        vocab: 152_064,
    }
}

pub fn qwen2_5_32b() -> LlmConfig {
    LlmConfig {
        name: "Qwen2.5-32B",
        hidden: 5120,
        layers: 64,
        heads: 40,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 27_648,
        vocab: 152_064,
    }
}

pub fn qwen3_32b() -> LlmConfig {
    LlmConfig {
        name: "Qwen3-32B",
        hidden: 5120,
        layers: 64,
        heads: 64,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 25_600,
        vocab: 151_936,
    }
}

pub fn llama3_1_70b() -> LlmConfig {
    LlmConfig {
        name: "Llama3.1-70B",
        hidden: 8192,
        layers: 80,
        heads: 64,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 28_672,
        vocab: 128_256,
    }
}

pub fn by_name(name: &str) -> Option<LlmConfig> {
    let n = name.to_lowercase().replace(['-', '.', '_'], "");
    for cfg in [qwen2_5_14b(), qwen2_5_32b(), qwen3_32b(), llama3_1_70b()] {
        if cfg.name.to_lowercase().replace(['-', '.', '_'], "") == n {
            return Some(cfg);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_counts_roughly_match_names() {
        assert!((qwen2_5_14b().params_approx() / 1e9 - 14.0).abs() < 3.0);
        assert!((qwen3_32b().params_approx() / 1e9 - 32.0).abs() < 6.0);
        assert!((llama3_1_70b().params_approx() / 1e9 - 70.0).abs() < 10.0);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("qwen2.5-14b").is_some());
        assert!(by_name("Llama3.1-70B").is_some());
        assert!(by_name("gpt-x").is_none());
    }

    #[test]
    fn gqa_everywhere() {
        for cfg in [qwen2_5_14b(), qwen2_5_32b(), qwen3_32b(), llama3_1_70b()] {
            assert!(cfg.heads % cfg.kv_heads == 0);
            assert!(cfg.heads / cfg.kv_heads >= 5 || cfg.kv_heads == 8);
        }
    }
}
