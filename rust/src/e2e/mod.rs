//! End-to-end LLM inference prediction (paper §V-D, §VI-D): model configs,
//! workload sampling, trace generation, communication modeling, and the
//! multi-method trace evaluator.

pub mod comm;
pub mod llm;
pub mod predict;
pub mod trace;
pub mod workload;
