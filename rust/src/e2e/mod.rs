//! End-to-end LLM inference primitives (paper §V-D, §VI-D): the model
//! registry, workload sampling, trace generation, communication modeling,
//! and the multi-method trace evaluator.
//!
//! These are the building blocks the declarative **Scenario API**
//! ([`crate::scenario`]) compiles down to; callers describe a serving
//! scenario as a [`crate::scenario::ScenarioSpec`] instead of hand-building
//! traces from these modules.

pub mod comm;
pub mod llm;
pub mod predict;
pub mod trace;
pub mod workload;
