//! Communication kernels (paper §V-D): a profiled baseline database of
//! collective latencies plus a random-forest regressor over it — "we profile
//! their performance across different network topologies and communication
//! volumes ... then apply a data-driven regression technique (e.g., Random
//! Forest)".
//!
//! The comm oracle is the ground-truth substitute (ring All-Reduce alpha-beta
//! model with a small-message floor and noise); the RF is what predictors
//! use at inference time.

use crate::forest::{ForestConfig, RandomForest};
use crate::hw::GpuSpec;
use crate::util::rng::Rng;

/// Ground-truth All-Reduce latency over `n` GPUs (ring algorithm).
pub fn allreduce_oracle(bytes: f64, n: u32, gpu: &GpuSpec, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ 0xC0111EC7);
    let n = n.max(2) as f64;
    let alpha = 14e-6 * (1.0 + 0.35 * (n - 2.0) / 6.0);
    let eff_bw = gpu.interconnect_gbs * 1e9 * 0.72;
    let ring = 2.0 * (n - 1.0) / n * bytes / eff_bw;
    // protocol switch bump for mid-size messages
    let bump = if (1e6..8e6).contains(&bytes) { 1.12 } else { 1.0 };
    (alpha + ring * bump) * rng.lognormal_factor(0.03)
}

/// Ground-truth point-to-point Send/Recv (PP stage boundary).
pub fn sendrecv_oracle(bytes: f64, gpu: &GpuSpec, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ 0x5E11D);
    let eff_bw = gpu.interconnect_gbs * 1e9 * 0.80;
    (7e-6 + bytes / eff_bw) * rng.lognormal_factor(0.03)
}

/// RF-based comm predictor trained on a profiled grid (the "baseline
/// performance database" of §V-D).
pub struct CommModel {
    allreduce: RandomForest,
    sendrecv: RandomForest,
}

fn features(bytes: f64, n: u32, gpu: &GpuSpec) -> Vec<f64> {
    vec![bytes.max(1.0).ln(), n as f64, (gpu.interconnect_gbs * 1e9).ln()]
}

impl CommModel {
    /// Profile `gpu`'s collectives and fit the regressors.
    pub fn train(gpu: &GpuSpec, seed: u64) -> CommModel {
        let mut xs_ar = Vec::new();
        let mut ys_ar = Vec::new();
        let mut xs_sr = Vec::new();
        let mut ys_sr = Vec::new();
        let sizes: Vec<f64> =
            (0..36).map(|i| 1024.0 * 2f64.powf(i as f64 * 0.5)).collect(); // 1KB..256MB
        for (i, &b) in sizes.iter().enumerate() {
            for n in [2u32, 4, 8] {
                for rep in 0..3u64 {
                    let s = seed ^ ((i as u64) << 16) ^ ((n as u64) << 8) ^ rep;
                    xs_ar.push(features(b, n, gpu));
                    ys_ar.push(allreduce_oracle(b, n, gpu, s).ln());
                }
            }
            for rep in 0..3u64 {
                let s = seed ^ ((i as u64) << 20) ^ rep;
                xs_sr.push(features(b, 2, gpu));
                ys_sr.push(sendrecv_oracle(b, gpu, s).ln());
            }
        }
        let cfg = ForestConfig { n_trees: 30, max_depth: 10, ..Default::default() };
        CommModel {
            allreduce: RandomForest::fit(&xs_ar, &ys_ar, &cfg),
            sendrecv: RandomForest::fit(&xs_sr, &ys_sr, &cfg),
        }
    }

    pub fn predict_allreduce(&self, bytes: f64, n: u32, gpu: &GpuSpec) -> f64 {
        self.allreduce.predict(&features(bytes, n, gpu)).exp()
    }

    pub fn predict_sendrecv(&self, bytes: f64, gpu: &GpuSpec) -> f64 {
        self.sendrecv.predict(&features(bytes, 2, gpu)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::util::stats::mape;

    #[test]
    fn ring_scales_with_bytes_and_n() {
        let a100 = gpu_by_name("A100").unwrap();
        let small = allreduce_oracle(1e5, 4, &a100, 1);
        let big = allreduce_oracle(1e8, 4, &a100, 1);
        assert!(big > 10.0 * small);
        let n2 = allreduce_oracle(1e8, 2, &a100, 1);
        let n8 = allreduce_oracle(1e8, 8, &a100, 1);
        assert!(n8 > n2);
    }

    #[test]
    fn nvlink_faster_than_pcie() {
        let a100 = gpu_by_name("A100").unwrap(); // NVLink 300GB/s
        let a40 = gpu_by_name("A40").unwrap(); // PCIe 32GB/s
        assert!(allreduce_oracle(1e8, 4, &a100, 1) < allreduce_oracle(1e8, 4, &a40, 1) / 3.0);
    }

    #[test]
    fn rf_fits_the_database() {
        let gpu = gpu_by_name("H800").unwrap();
        let m = CommModel::train(&gpu, 7);
        let mut pred = Vec::new();
        let mut actual = Vec::new();
        for i in 0..40 {
            let bytes = 2048.0 * 2f64.powf(i as f64 * 0.4);
            for n in [2u32, 4, 8] {
                pred.push(m.predict_allreduce(bytes, n, &gpu));
                actual.push(allreduce_oracle(bytes, n, &gpu, 10_000 + i));
            }
        }
        let err = mape(&pred, &actual);
        assert!(err < 15.0, "comm RF MAPE {err}%");
    }
}
