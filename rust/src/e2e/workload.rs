//! Workload generation (paper §VI-D): request batches sampled in the style
//! of the Arxiv-Summarization and Splitwise datasets — `arxiv_*` averages
//! 2,630 input tokens, `splitwise_*` averages 982; output lengths range
//! 5..4056 tokens.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub input_len: u32,
    pub output_len: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    Arxiv,
    Splitwise,
}

impl WorkloadKind {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Arxiv => "arxiv",
            WorkloadKind::Splitwise => "splitwise",
        }
    }

    pub fn from_name(s: &str) -> Option<WorkloadKind> {
        match s {
            "arxiv" => Some(WorkloadKind::Arxiv),
            "splitwise" => Some(WorkloadKind::Splitwise),
            _ => None,
        }
    }

    pub fn mean_input(&self) -> f64 {
        match self {
            WorkloadKind::Arxiv => 2630.0,
            WorkloadKind::Splitwise => 982.0,
        }
    }
}

/// Sample a batch of `batch_size` requests (e.g. arxiv_8 = Arxiv batch 8).
pub fn sample_batch(kind: WorkloadKind, batch_size: usize, rng: &mut Rng) -> Vec<Request> {
    (0..batch_size)
        .map(|_| {
            // lognormal-ish input lengths around the dataset mean
            let f = (rng.normal() * 0.45).exp();
            let input_len = (kind.mean_input() * f).round().clamp(16.0, 16_384.0) as u32;
            let output_len = rng.log_range_u32(5, 4_056);
            Request { input_len, output_len }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_match_paper() {
        let mut rng = Rng::new(1);
        for (kind, lo, hi) in
            [(WorkloadKind::Arxiv, 2100.0, 3300.0), (WorkloadKind::Splitwise, 800.0, 1250.0)]
        {
            let reqs: Vec<Request> = (0..200)
                .flat_map(|_| sample_batch(kind, 16, &mut rng))
                .collect();
            let mean = reqs.iter().map(|r| r.input_len as f64).sum::<f64>() / reqs.len() as f64;
            assert!((lo..hi).contains(&mean), "{kind:?} mean {mean}");
        }
    }

    #[test]
    fn outputs_in_paper_range() {
        let mut rng = Rng::new(2);
        for r in sample_batch(WorkloadKind::Arxiv, 500, &mut rng) {
            assert!((5..=4056).contains(&r.output_len));
        }
    }
}
