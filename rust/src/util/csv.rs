//! Minimal CSV I/O (no quoting — all our fields are numeric or simple
//! identifiers). Used for dataset persistence and experiment outputs.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    out: BufWriter<std::fs::File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = std::fs::File::create(&path)
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        let mut out = BufWriter::new(f);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        if fields.len() != self.cols {
            bail!("csv row arity {} != header {}", fields.len(), self.cols);
        }
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

pub struct CsvData {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvData {
    pub fn col_idx(&self, name: &str) -> Result<usize> {
        self.header
            .iter()
            .position(|h| h == name)
            .with_context(|| format!("csv column {name:?} missing from {:?}", self.header))
    }

    pub fn f64_col(&self, name: &str) -> Result<Vec<f64>> {
        let i = self.col_idx(name)?;
        self.rows
            .iter()
            .map(|r| r[i].parse::<f64>().with_context(|| format!("parse {:?}", r[i])))
            .collect()
    }
}

pub fn read_csv<P: AsRef<Path>>(path: P) -> Result<CsvData> {
    let f = std::fs::File::open(&path).with_context(|| format!("open {:?}", path.as_ref()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = match lines.next() {
        Some(h) => h?.split(',').map(|s| s.to_string()).collect::<Vec<_>>(),
        None => bail!("empty csv"),
    };
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let row: Vec<String> = line.split(',').map(|s| s.to_string()).collect();
        if row.len() != header.len() {
            bail!("row arity {} != header {}", row.len(), header.len());
        }
        rows.push(row);
    }
    Ok(CsvData { header, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("synperf_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "2.5".into()]).unwrap();
        w.row(&["3".into(), "4.5".into()]).unwrap();
        w.finish().unwrap();
        let d = read_csv(&path).unwrap();
        assert_eq!(d.header, vec!["a", "b"]);
        assert_eq!(d.rows.len(), 2);
        assert_eq!(d.f64_col("b").unwrap(), vec![2.5, 4.5]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn arity_errors() {
        let dir = std::env::temp_dir().join("synperf_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
