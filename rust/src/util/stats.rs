//! Statistics helpers: the error metrics reported by the paper (MAPE, signed
//! relative error, geometric-mean speedup, percentiles, Pearson correlation)
//! plus small fitting utilities.

/// Mean Absolute Percentage Error (%), the paper's headline metric.
/// The denominator clamp works on |actual| so a negative actual (signed
/// residuals, deltas) keeps its magnitude instead of collapsing to 1e-12
/// and exploding the reported error.
pub fn mape(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    assert!(!pred.is_empty());
    let s: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| ((p - a) / a.abs().max(1e-12)).abs())
        .sum();
    100.0 * s / pred.len() as f64
}

/// Signed relative error (%) — used by Fig. 7 to show over/under-estimation.
/// |actual| in the denominator preserves the sign convention (positive =
/// over-estimate) for negative actuals too.
pub fn signed_rel_err(pred: f64, actual: f64) -> f64 {
    100.0 * (pred - actual) / actual.abs().max(1e-12)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean, defined on any input. An empty slice is the neutral
/// ratio 1.0 (a speedup summary over zero points must not poison
/// downstream aggregates with NaN), and zero/negative elements — where a
/// geomean is not mathematically meaningful — are clamped to 1e-12 so one
/// stray value degrades the estimate instead of collapsing it to 0/-inf.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, q in [0, 100]. Empty input yields NaN
/// (like `mean`); NaN elements sort last via `total_cmp` instead of
/// panicking the comparator.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt()).max(1e-300)
}

/// CDF sample points (sorted values with cumulative fraction) for Fig. 8.
pub fn cdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len() as f64;
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Fixed-bin mergeable latency histogram on a logarithmic grid: 20 bins per
/// decade over [1 µs, 10 ks), 200 bins total. Bin layout is a compile-time
/// constant, so histograms built on different replicas (or different runs)
/// merge by adding counts and aggregate reports stay byte-deterministic —
/// identical insert multisets always produce identical bins regardless of
/// insert order or thread count. Values below the grid (including 0 and
/// NaN) land in bin 0; values above it land in the last bin; exact
/// count/sum/min/max are carried alongside so the tails stay sharp.
/// Percentile estimates are bin-resolution: each bin spans a factor of
/// 10^(1/20) ≈ 12 %.
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    /// Lower edge of the grid, seconds.
    pub const LO: f64 = 1e-6;
    pub const BINS_PER_DECADE: usize = 20;
    pub const DECADES: usize = 10;
    pub const NUM_BINS: usize = Self::BINS_PER_DECADE * Self::DECADES;

    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: vec![0; Self::NUM_BINS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bin_of(v: f64) -> usize {
        if !(v > Self::LO) {
            // underflow — and NaN, which fails every comparison
            return 0;
        }
        let idx = ((v / Self::LO).log10() * Self::BINS_PER_DECADE as f64).floor() as isize;
        idx.clamp(0, Self::NUM_BINS as isize - 1) as usize
    }

    /// Upper edge of bin `i` — what the percentile estimator reports.
    fn bin_hi(i: usize) -> f64 {
        Self::LO * 10f64.powf((i + 1) as f64 / Self::BINS_PER_DECADE as f64)
    }

    pub fn insert(&mut self, v: f64) {
        self.counts[Self::bin_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.min
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.max
    }

    /// Nearest-rank percentile at bin resolution: the upper edge of the bin
    /// holding the ⌈q/100·n⌉-th ranked sample, clamped into [min, max] so
    /// the extremes are exact. q ≤ 0 returns the exact min, q ≥ 100 the
    /// exact max; the empty histogram returns NaN.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 100.0 {
            return self.max;
        }
        let rank = ((q / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                // max().min() rather than clamp(): a histogram fed only
                // NaN keeps min=+inf/max=-inf, and clamp would panic
                return Self::bin_hi(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// Sparse view for serialization: (bin index, count) for occupied bins,
    /// in index order.
    pub fn nonzero_bins(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
    }

    /// Rebuild from a sparse serialization. Returns None on an out-of-range
    /// bin index. An empty bin set yields the canonical empty histogram
    /// (whatever min/max the wire carried).
    pub fn from_parts(bins: &[(usize, u64)], sum: f64, min: f64, max: f64) -> Option<LogHistogram> {
        let mut h = LogHistogram::new();
        for &(i, c) in bins {
            if i >= Self::NUM_BINS {
                return None;
            }
            h.counts[i] += c;
            h.count += c;
        }
        if h.count > 0 {
            h.sum = sum;
            h.min = min;
            h.max = max;
        }
        Some(h)
    }
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Ordinary least squares for small systems: solves X^T X beta = X^T y via
/// Gaussian elimination. Rows of `x` are samples (with any intercept column
/// already included). Used by the Linear baseline (paper [29]).
pub fn ols(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty());
    let d = x[0].len();
    let mut xtx = vec![vec![0.0; d]; d];
    let mut xty = vec![0.0; d];
    for (row, &t) in x.iter().zip(y) {
        for i in 0..d {
            xty[i] += row[i] * t;
            for j in 0..d {
                xtx[i][j] += row[i] * row[j];
            }
        }
    }
    // ridge epsilon for numerical safety
    for i in 0..d {
        xtx[i][i] += 1e-9;
    }
    solve(&mut xtx, &mut xty);
    xty
}

/// In-place Gaussian elimination with partial pivoting; solution left in `b`.
fn solve(a: &mut [Vec<f64>], b: &mut [f64]) {
    let n = b.len();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let div = a[col][col];
        if div.abs() < 1e-300 {
            continue;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[r][col] / div;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    for i in 0..n {
        if a[i][i].abs() > 1e-300 {
            b[i] /= a[i][i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mape_basics() {
        assert!((mape(&[1.1, 0.9], &[1.0, 1.0]) - 10.0).abs() < 1e-9);
        assert_eq!(mape(&[2.0], &[2.0]), 0.0);
    }

    #[test]
    fn signed_err_sign() {
        assert!(signed_rel_err(1.2, 1.0) > 0.0);
        assert!(signed_rel_err(0.8, 1.0) < 0.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        let neg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn ols_recovers_plane() {
        // y = 3 + 2a - b
        let x: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![1.0, i as f64, (i * i % 7) as f64])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 + 2.0 * r[1] - r[2]).collect();
        let beta = ols(&x, &y);
        assert!((beta[0] - 3.0).abs() < 1e-6);
        assert!((beta[1] - 2.0).abs() < 1e-6);
        assert!((beta[2] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn cdf_monotone() {
        let c = cdf(&[3.0, 1.0, 2.0]);
        assert_eq!(c[0].0, 1.0);
        assert!((c[2].1 - 1.0).abs() < 1e-12);
        assert!(c.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
    }

    // --- regression tests for the PR-6 bugfix batch ---

    #[test]
    fn mape_handles_negative_actuals() {
        // pre-fix: a.max(1e-12) clamped -2.0 to 1e-12 and the error blew up
        // to ~1e14 %; |actual| keeps it at the true 50 %
        let m = mape(&[-1.0], &[-2.0]);
        assert!((m - 50.0).abs() < 1e-9, "mape on negative actual: {m}");
        // zero actual still falls back to the epsilon clamp, not a division
        // by zero
        assert!(mape(&[1.0], &[0.0]).is_finite());
    }

    #[test]
    fn signed_rel_err_keeps_sign_convention_for_negative_actuals() {
        // pred above actual must read as over-estimation regardless of the
        // actual's sign; pre-fix the clamped denominator flipped/blew it up
        let e = signed_rel_err(-1.0, -2.0);
        assert!((e - 50.0).abs() < 1e-9, "over-estimate of a negative actual: {e}");
        let e = signed_rel_err(-3.0, -2.0);
        assert!((e + 50.0).abs() < 1e-9, "under-estimate of a negative actual: {e}");
    }

    #[test]
    fn percentile_empty_is_nan_not_a_panic() {
        assert!(percentile(&[], 50.0).is_nan());
    }

    // --- regression tests for the PR-9 geomean edge cases ---

    #[test]
    fn geomean_empty_is_the_neutral_ratio() {
        // pre-fix: NaN, which poisoned every tune summary over zero
        // diagnosed points
        assert_eq!(geomean(&[]), 1.0);
    }

    #[test]
    fn geomean_survives_zero_and_negative_elements() {
        // a geomean is only meaningful on positive data; stray non-positive
        // elements are clamped instead of collapsing the whole estimate
        assert!(geomean(&[0.0, 4.0]) > 0.0);
        assert!(geomean(&[-3.0]).is_finite());
        assert!(geomean(&[1.0, 0.0, 1.0]).is_finite());
        // and the clamp does not disturb ordinary inputs
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-9, "geomean(2, 8) = {g}");
        assert!((geomean(&[1.5]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_and_cdf_tolerate_nan_input() {
        // pre-fix: partial_cmp().unwrap() panicked inside sort on any NaN
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        // total_cmp sorts NaN last, so low quantiles are still meaningful
        assert_eq!(percentile(&xs, 0.0), 1.0);
        let c = cdf(&xs);
        assert_eq!(c[0].0, 1.0);
        assert!(c[3].0.is_nan());
    }

    #[test]
    fn pearson_unchanged_by_dead_term_removal() {
        // the `* (n / n)` factor was exactly 1 for every non-empty input;
        // removing it must not move the statistic
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys = [3.0, 1.0, 4.0, 1.0];
        let r = pearson(&xs, &ys);
        assert!((-1.0..=1.0).contains(&r));
        assert!((r - pearson(&ys, &xs)).abs() < 1e-12, "symmetry");
    }

    #[test]
    fn histogram_percentiles_bracket_exact_ones() {
        let mut h = LogHistogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 1e-3).collect();
        for &x in &xs {
            h.insert(x);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - mean(&xs)).abs() < 1e-9);
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 1.0);
        for q in [50.0, 95.0, 99.0] {
            let exact = percentile(&xs, q);
            let est = h.percentile(q);
            // one log bin is a 10^(1/20) ≈ 1.122x span; the estimate sits at
            // the bin's upper edge, so it is ≥ exact and within ~12.3 %
            assert!(est >= exact * 0.999, "p{q}: est {est} < exact {exact}");
            assert!(est <= exact * 1.123, "p{q}: est {est} too far above exact {exact}");
        }
        assert_eq!(h.percentile(0.0), 1e-3);
        assert_eq!(h.percentile(100.0), 1.0);
    }

    #[test]
    fn histogram_merge_equals_bulk_insert() {
        let (mut a, mut b, mut whole) = (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for i in 0..500 {
            let v = 1e-5 * (1.0 + i as f64);
            if i % 2 == 0 {
                a.insert(v);
            } else {
                b.insert(v);
            }
            whole.insert(v);
        }
        a.merge(&b);
        assert_eq!(a, whole, "merge must equal the bulk-inserted histogram");
    }

    #[test]
    fn histogram_edges_and_empty() {
        let h = LogHistogram::new();
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());
        let mut h = LogHistogram::new();
        h.insert(0.0); // below the grid -> bin 0
        h.insert(1e9); // above the grid -> last bin
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e9);
        // clamped into [min, max] even though the bins saturate
        assert_eq!(h.percentile(100.0), 1e9);
        assert_eq!(h.percentile(0.0), 0.0);
    }

    #[test]
    fn histogram_sparse_roundtrip() {
        let mut h = LogHistogram::new();
        for v in [3e-4, 2.5e-1, 2.5e-1, 7.0] {
            h.insert(v);
        }
        let bins: Vec<(usize, u64)> = h.nonzero_bins().collect();
        assert!(bins.len() <= 3);
        let back = LogHistogram::from_parts(&bins, h.sum(), h.min(), h.max()).unwrap();
        assert_eq!(back, h);
        assert!(LogHistogram::from_parts(&[(LogHistogram::NUM_BINS, 1)], 0.0, 0.0, 0.0).is_none());
    }
}
