//! Statistics helpers: the error metrics reported by the paper (MAPE, signed
//! relative error, geometric-mean speedup, percentiles, Pearson correlation)
//! plus small fitting utilities.

/// Mean Absolute Percentage Error (%), the paper's headline metric.
pub fn mape(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    assert!(!pred.is_empty());
    let s: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| ((p - a) / a.max(1e-12)).abs())
        .sum();
    100.0 * s / pred.len() as f64
}

/// Signed relative error (%) — used by Fig. 7 to show over/under-estimation.
pub fn signed_rel_err(pred: f64, actual: f64) -> f64 {
    100.0 * (pred - actual) / actual.max(1e-12)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt()).max(1e-300) * (n / n)
}

/// CDF sample points (sorted values with cumulative fraction) for Fig. 8.
pub fn cdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Ordinary least squares for small systems: solves X^T X beta = X^T y via
/// Gaussian elimination. Rows of `x` are samples (with any intercept column
/// already included). Used by the Linear baseline (paper [29]).
pub fn ols(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty());
    let d = x[0].len();
    let mut xtx = vec![vec![0.0; d]; d];
    let mut xty = vec![0.0; d];
    for (row, &t) in x.iter().zip(y) {
        for i in 0..d {
            xty[i] += row[i] * t;
            for j in 0..d {
                xtx[i][j] += row[i] * row[j];
            }
        }
    }
    // ridge epsilon for numerical safety
    for i in 0..d {
        xtx[i][i] += 1e-9;
    }
    solve(&mut xtx, &mut xty);
    xty
}

/// In-place Gaussian elimination with partial pivoting; solution left in `b`.
fn solve(a: &mut [Vec<f64>], b: &mut [f64]) {
    let n = b.len();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let div = a[col][col];
        if div.abs() < 1e-300 {
            continue;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[r][col] / div;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    for i in 0..n {
        if a[i][i].abs() > 1e-300 {
            b[i] /= a[i][i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mape_basics() {
        assert!((mape(&[1.1, 0.9], &[1.0, 1.0]) - 10.0).abs() < 1e-9);
        assert_eq!(mape(&[2.0], &[2.0]), 0.0);
    }

    #[test]
    fn signed_err_sign() {
        assert!(signed_rel_err(1.2, 1.0) > 0.0);
        assert!(signed_rel_err(0.8, 1.0) < 0.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        let neg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn ols_recovers_plane() {
        // y = 3 + 2a - b
        let x: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![1.0, i as f64, (i * i % 7) as f64])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 + 2.0 * r[1] - r[2]).collect();
        let beta = ols(&x, &y);
        assert!((beta[0] - 3.0).abs() < 1e-6);
        assert!((beta[1] - 2.0).abs() < 1e-6);
        assert!((beta[2] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn cdf_monotone() {
        let c = cdf(&[3.0, 1.0, 2.0]);
        assert_eq!(c[0].0, 1.0);
        assert!((c[2].1 - 1.0).abs() < 1e-12);
        assert!(c.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
    }
}
