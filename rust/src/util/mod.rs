//! Shared utilities: deterministic RNG, statistics, CSV/JSON I/O, ASCII
//! tables, argument parsing, and the micro-benchmark harness. Everything
//! here is dependency-free (the offline vendor set only carries the `xla`
//! crate's closure), which keeps the runtime path self-contained.

pub mod argp;
pub mod bench;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Property-based test driver: runs `cases` random trials, reporting the
/// failing seed on panic so failures reproduce (`proptest` substitute).
pub fn prop_check<F: Fn(&mut rng::Rng)>(name: &str, cases: usize, f: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut r = rng::Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut r)));
        if let Err(e) = result {
            eprintln!("property {name:?} failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}
