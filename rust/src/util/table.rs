//! ASCII table rendering for the experiment harness — every paper table /
//! figure series is printed through this so outputs are uniform and easy to
//! diff against EXPERIMENTS.md.

pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:<width$} |", c, width = w));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: fixed-point with n decimals.
pub fn f(v: f64, n: usize) -> String {
    format!("{:.*}", n, v)
}

/// Format helper: percentage with one decimal.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xxxx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| xxxx | 1    |"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
