//! Tiny argument parser for the CLI (no `clap` in the offline vendor set).
//! Supports `subcommand --flag value --switch positional` layouts.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse a raw argument list (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.str_opt(name)
            .with_context(|| format!("missing required flag --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} expects a number, got {s:?}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn subcommand(&self) -> Result<(&str, Args)> {
        if self.positional.is_empty() {
            bail!("expected a subcommand");
        }
        let mut rest = self.clone();
        let sub = rest.positional.remove(0);
        // leak is fine: one subcommand string per process invocation
        Ok((Box::leak(sub.into_boxed_str()), rest))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn flags_values_switches() {
        // note: bare switches bind a following bare token as their value, so
        // positionals go before switches (documented CLI convention)
        let a = parse("train pos1 --kernel gemm --steps 100 --quiet");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.str_opt("kernel"), Some("gemm"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.has("quiet"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--lr=0.01 --name=x");
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.01);
        assert_eq!(a.str_opt("name"), Some("x"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("run");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert!(a.req("missing").is_err());
        let bad = parse("--n abc");
        assert!(bad.usize_or("n", 0).is_err());
    }

    #[test]
    fn subcommand_split() {
        let a = parse("experiment table8 --fast");
        let (sub, rest) = a.subcommand().unwrap();
        assert_eq!(sub, "experiment");
        assert_eq!(rest.positional, vec!["table8"]);
        assert!(rest.has("fast"));
    }
}
