//! Deterministic PRNG utilities (no external `rand` crate available in the
//! offline vendor set): SplitMix64 seeding + xoshiro256** core, with the
//! distribution helpers the dataset samplers and the oracle need.

/// SplitMix64 — used to expand a single u64 seed into xoshiro state and as a
/// cheap standalone generator for hashing-style derivations.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream for a labelled sub-task (e.g. per-sample).
    pub fn fork(&self, label: u64) -> Rng {
        let mut sm = self.s[0] ^ label.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Spans that fit the f64
    /// mantissa (≤ 2^53) keep the original float path bit-for-bit, so every
    /// pinned sampled stream (proptest seeds, golden traces) is unchanged.
    /// Wider spans take an unbiased masked-rejection integer path instead:
    /// the old `hi - lo + 1` overflowed at `(0, u64::MAX)` (panic in debug,
    /// span 0 in release) and the f64 round-trip collapses/biases values
    /// beyond 2^53.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo).wrapping_add(1); // 0 encodes the full u64 range
        if span != 0 && span <= (1u64 << 53) {
            let v = (self.f64() * span as f64) as u64;
            return lo + v.min(span - 1);
        }
        if span == 0 {
            return self.next_u64();
        }
        // masked rejection: draw span.next_power_of_two()-sized words and
        // keep the first below span — expected < 2 draws per call
        let mask = u64::MAX >> span.leading_zeros();
        loop {
            let v = self.next_u64() & mask;
            if v < span {
                return lo.wrapping_add(v);
            }
        }
    }

    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.range_u64(lo as u64, hi as u64) as u32
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform float in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Log-uniform integer in [lo, hi] — matches the paper's wide parameter
    /// ranges (e.g. M in [2, 131072]) where uniform sampling would almost
    /// never produce small shapes.
    pub fn log_range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo >= 1 && lo <= hi);
        let l = (lo as f64).ln();
        let h = (hi as f64).ln();
        let v = self.range_f64(l, h).exp().round() as u32;
        v.clamp(lo, hi)
    }

    /// Exponential variate with the given rate (events/sec) — the
    /// inter-arrival gap of the cluster simulator's Poisson process.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // 1 - f64() is in (0, 1], so ln() is finite and the gap >= 0
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (u1, u2) = (self.f64().max(1e-12), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Multiplicative lognormal factor with given sigma (mean approx 1).
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range_usize(0, items.len() - 1)]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_is_independent() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            let i = r.range_u32(3, 9);
            assert!((3..=9).contains(&i));
        }
    }

    #[test]
    fn log_range_covers_decades() {
        let mut r = Rng::new(2);
        let mut small = 0;
        let mut large = 0;
        for _ in 0..2_000 {
            let v = r.log_range_u32(2, 131_072);
            assert!((2..=131_072).contains(&v));
            if v < 64 {
                small += 1;
            }
            if v > 16_384 {
                large += 1;
            }
        }
        assert!(small > 200, "log sampling should hit small values: {small}");
        assert!(large > 200, "log sampling should hit large values: {large}");
    }

    #[test]
    fn range_u64_narrow_spans_keep_the_pinned_float_path() {
        // the wide-span fix must not move a single draw for spans <= 2^53 —
        // replay the pre-fix formula against a cloned stream
        let mut fixed = Rng::new(99);
        let mut replay = fixed.clone();
        for (lo, hi) in [(0u64, 0u64), (3, 9), (0, (1 << 53) - 1), (7, 7 + (1 << 53) - 1)] {
            for _ in 0..200 {
                let span = hi - lo + 1;
                let old = {
                    let v = (replay.f64() * span as f64) as u64;
                    lo + v.min(span - 1)
                };
                assert_eq!(fixed.range_u64(lo, hi), old, "float path drifted at ({lo}, {hi})");
            }
        }
    }

    #[test]
    fn range_u64_full_span_no_longer_overflows() {
        // pre-fix: hi - lo + 1 overflowed (debug panic / span 0 in release)
        let mut r = Rng::new(6);
        let mut high_half = 0;
        for _ in 0..1_000 {
            let v = r.range_u64(0, u64::MAX);
            if v > u64::MAX / 2 {
                high_half += 1;
            }
        }
        assert!((300..=700).contains(&high_half), "full-span draws skewed: {high_half}");
    }

    #[test]
    fn range_u64_wide_spans_stay_in_bounds_and_reach_past_2p53() {
        // pre-fix the f64 round-trip could neither represent nor fairly
        // reach offsets beyond 2^53
        let (lo, hi) = (5u64, 5 + (1 << 60));
        let mut r = Rng::new(7);
        let mut beyond = 0;
        for _ in 0..1_000 {
            let v = r.range_u64(lo, hi);
            assert!((lo..=hi).contains(&v));
            if v - lo > (1 << 53) {
                beyond += 1;
            }
        }
        // P(v - lo <= 2^53) = 2^-7 per draw, so ~992 of 1000 land beyond
        assert!(beyond > 900, "wide span rarely passes 2^53: {beyond}");
        // an exact-boundary wide case: [u64::MAX - 1, u64::MAX]
        for _ in 0..100 {
            let v = r.range_u64(u64::MAX - 1, u64::MAX);
            assert!(v >= u64::MAX - 1);
        }
    }

    #[test]
    fn exponential_gaps_are_nonnegative_with_the_right_mean() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let g = r.exponential(2.0);
            assert!(g >= 0.0 && g.is_finite());
            sum += g;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean gap {mean}, want ~0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_factor_mean_near_one() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.lognormal_factor(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
