//! Hand-rolled micro-benchmark harness (criterion is not in the offline
//! vendor set). Reports min/median/mean over timed iterations with warmup,
//! matching what the `cargo bench` targets print.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters   min {:>12}   median {:>12}   mean {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns)
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Time `f` adaptively: warm up, then run enough iterations to cover
/// ~`budget_ms` milliseconds (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, min_iters: usize, mut f: F) -> BenchResult {
    // warmup
    f();
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let budget = budget_ms * 1_000_000;
    let iters = ((budget / once) as usize).clamp(min_iters, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min_ns = samples[0];
    let median_ns = samples[samples.len() / 2];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult { name: name.to_string(), iters, min_ns, median_ns, mean_ns }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let r = bench("noop", 5, 10, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.min_ns <= r.median_ns);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
