//! Hand-rolled micro-benchmark harness (criterion is not in the offline
//! vendor set). Reports min/median/mean over timed iterations with warmup,
//! matching what the `cargo bench` targets print; results can also be
//! serialized as JSON ([`write_json`]) so runs are diffable across PRs
//! (`BENCH_PR*.json` perf-trajectory files).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters   min {:>12}   median {:>12}   mean {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns)
        )
    }

    /// One JSON object per bench — stable field names for the perf
    /// trajectory files.
    pub fn json(&self) -> String {
        let esc: String = self
            .name
            .chars()
            .flat_map(|c| match c {
                '"' | '\\' => vec!['\\', c],
                _ => vec![c],
            })
            .collect();
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"min_ns\":{:.1},\"median_ns\":{:.1},\"mean_ns\":{:.1}}}",
            esc, self.iters, self.min_ns, self.median_ns, self.mean_ns
        )
    }
}

/// Write results as a JSON object with a `results` array — the bench
/// binaries' `--json <path>` output, consumed by CI artifacts and the
/// committed BENCH_PR*.json files (an object, not a bare array, so those
/// files can carry metadata fields alongside `results` and regeneration
/// keeps the same shape).
pub fn write_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let body: Vec<String> = results.iter().map(|r| format!("    {}", r.json())).collect();
    std::fs::write(path, format!("{{\n  \"results\": [\n{}\n  ]\n}}\n", body.join(",\n")))
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Time `f` adaptively: warm up, then run enough iterations to cover
/// ~`budget_ms` milliseconds (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, min_iters: usize, mut f: F) -> BenchResult {
    // warmup
    f();
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let budget = budget_ms * 1_000_000;
    let iters = ((budget / once) as usize).clamp(min_iters, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min_ns = samples[0];
    let median_ns = samples[samples.len() / 2];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult { name: name.to_string(), iters, min_ns, median_ns, mean_ns }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let r = bench("noop", 5, 10, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.min_ns <= r.median_ns);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let r = BenchResult {
            name: "dsf/\"quoted\"".to_string(),
            iters: 42,
            min_ns: 100.0,
            median_ns: 150.5,
            mean_ns: 160.25,
        };
        let parsed =
            crate::util::json::parse(&format!("{{\"results\":[{}]}}", r.json())).unwrap();
        let obj = &parsed.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(obj.get("name").unwrap().as_str().unwrap(), "dsf/\"quoted\"");
        assert_eq!(obj.get("iters").unwrap().as_usize().unwrap(), 42);
        assert!((obj.get("median_ns").unwrap().as_f64().unwrap() - 150.5).abs() < 1e-9);
    }
}
