//! Minimal JSON parser — just enough to read `artifacts/manifest.json`
//! (objects, arrays, numbers, strings, booleans, null). No external serde
//! facade is available in the offline vendor set.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

pub fn parse(input: &str) -> Result<Json> {
    let bytes: Vec<char> = input.chars().collect();
    let mut pos = 0usize;
    let v = parse_value(&bytes, &mut pos)?;
    skip_ws(&bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[char], pos: &mut usize) {
    while *pos < b.len() && b[*pos].is_whitespace() {
        *pos += 1;
    }
}

fn expect(b: &[char], pos: &mut usize, c: char) -> Result<()> {
    skip_ws(b, pos);
    if *pos >= b.len() || b[*pos] != c {
        bail!("expected {c:?} at {pos:?}");
    }
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[char], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        '{' => parse_obj(b, pos),
        '[' => parse_arr(b, pos),
        '"' => Ok(Json::Str(parse_string(b, pos)?)),
        't' => parse_lit(b, pos, "true", Json::Bool(true)),
        'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[char], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    for c in lit.chars() {
        if *pos >= b.len() || b[*pos] != c {
            bail!("bad literal at {pos}");
        }
        *pos += 1;
    }
    Ok(v)
}

fn parse_num(b: &[char], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && (b[*pos].is_ascii_digit() || "+-.eE".contains(b[*pos]))
    {
        *pos += 1;
    }
    let s: String = b[start..*pos].iter().collect();
    Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
}

fn parse_string(b: &[char], pos: &mut usize) -> Result<String> {
    expect(b, pos, '"')?;
    let mut s = String::new();
    while *pos < b.len() {
        let c = b[*pos];
        *pos += 1;
        match c {
            '"' => return Ok(s),
            '\\' => {
                if *pos >= b.len() {
                    bail!("bad escape");
                }
                let e = b[*pos];
                *pos += 1;
                s.push(match e {
                    'n' => '\n',
                    't' => '\t',
                    'r' => '\r',
                    '/' => '/',
                    '"' => '"',
                    '\\' => '\\',
                    'u' => {
                        let hex: String = b[*pos..*pos + 4].iter().collect();
                        *pos += 4;
                        char::from_u32(u32::from_str_radix(&hex, 16)?).unwrap_or('?')
                    }
                    other => bail!("unsupported escape \\{other}"),
                });
            }
            _ => s.push(c),
        }
    }
    bail!("unterminated string")
}

fn parse_arr(b: &[char], pos: &mut usize) -> Result<Json> {
    expect(b, pos, '[')?;
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == ']' {
        *pos += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == ',' {
            *pos += 1;
            continue;
        }
        expect(b, pos, ']')?;
        return Ok(Json::Arr(v));
    }
}

fn parse_obj(b: &[char], pos: &mut usize) -> Result<Json> {
    expect(b, pos, '{')?;
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == '}' {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, ':')?;
        let val = parse_value(b, pos)?;
        m.insert(key, val);
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == ',' {
            *pos += 1;
            continue;
        }
        expect(b, pos, '}')?;
        return Ok(Json::Obj(m));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like() {
        let j = parse(
            r#"{"feature_dim": 32, "layers": [[32, 256], [256, 1]],
                "fwd_args": ["theta", "bn", "x"], "lr": 0.001, "ok": true}"#,
        )
        .unwrap();
        assert_eq!(j.get("feature_dim").unwrap().as_usize(), Some(32));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].as_arr().unwrap()[1].as_usize(), Some(256));
        assert_eq!(
            j.get("fwd_args").unwrap().as_arr().unwrap()[2].as_str(),
            Some("x")
        );
        assert_eq!(j.get("lr").unwrap().as_f64(), Some(0.001));
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{key: 1}").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn string_escapes() {
        let j = parse(r#""a\nbA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nbA"));
    }
}
