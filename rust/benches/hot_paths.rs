//! `cargo bench --bench hot_paths` — microbenchmarks of the Layer-3 request
//! path (hand-rolled harness; criterion is not in the offline vendor set):
//!
//!   decompose -> schedule -> features   (the analytical front half)
//!   oracle measurement                  (dataset generation throughput)
//!   scenario compile                    (ScenarioSpec -> phase-tagged op streams)
//!   native MLP forward                  (artifact-free fallback path)
//!   MLP forward via PJRT (b1 / b256 / b1024)
//!   end-to-end single prediction       (the Fig. 7 "SynPerf time" path)
//!   coordinator service throughput
//!
//! Flags (after `--`):
//!   --json <path>   also write results as JSON (BENCH_PR*.json schema)
//!   --smoke         minimal iteration counts — CI smoke so the binary
//!                   can't rot; timings are NOT meaningful in this mode
//!                   (also enabled by SYNPERF_BENCH_SMOKE=1)

use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::dataset;
use synperf::engine::PredictionEngine;
use synperf::features::FeatureSet;
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::oracle;
use synperf::runtime::Engine;
use synperf::sched::schedule;
use synperf::util::argp::Args;
use synperf::util::bench::{bench, black_box, write_json, BenchResult};

struct Harness {
    smoke: bool,
    results: Vec<BenchResult>,
}

impl Harness {
    fn run(&mut self, name: &str, budget_ms: u64, min_iters: usize, f: impl FnMut()) {
        let (budget_ms, min_iters) = if self.smoke { (1, 2) } else { (budget_ms, min_iters) };
        let r = bench(name, budget_ms, min_iters, f);
        println!("{}", r.report());
        self.results.push(r);
    }
}

fn main() {
    // cargo passes a bare `--bench` to bench binaries; Args absorbs it as a
    // switch, so only our own flags matter here
    let args = Args::parse(std::env::args().skip(1));
    let smoke = args.has("smoke")
        || std::env::var("SYNPERF_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let mut h = Harness { smoke, results: Vec::new() };

    run_benches(&mut h, smoke);

    if let Some(path) = args.str_opt("json") {
        write_json(path, &h.results).expect("write bench json");
        println!("\nwrote {} bench results to {path}", h.results.len());
    }
}

fn run_benches(h: &mut Harness, smoke: bool) {
    let gpu = hw::gpu_by_name("A100").unwrap();
    let cfg = KernelConfig::Gemm { m: 4096, n: 11008, k: 4096, dtype: DType::Bf16 };
    let attn = KernelConfig::Attention {
        batch: vec![(2048, 2048); 8],
        nh: 32,
        nkv: 8,
        hd: 128,
        causal: true,
        fa3: false,
    };

    println!("== analytical front half ==");
    // the two perf-acceptance configs: full decompose -> schedule ->
    // features chain (grouped closed form: O(groups + num_sms))
    h.run("dsf/gemm-4096x11008x4096", 300, 20, || {
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        black_box(FeatureSet::analyze(&d, &dist, &gpu));
    });
    h.run("dsf/attention-8x2048-causal", 300, 20, || {
        let d = attn.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        black_box(FeatureSet::analyze(&d, &dist, &gpu));
    });
    h.run("decompose/gemm-4096x11008x4096", 200, 20, || {
        black_box(cfg.decompose(&gpu));
    });
    let d = cfg.decompose(&gpu);
    h.run("schedule/hardware-rr", 200, 20, || {
        black_box(schedule(&d, &gpu));
    });
    let dist = schedule(&d, &gpu);
    h.run("features/analyze", 200, 20, || {
        black_box(FeatureSet::analyze(&d, &dist, &gpu));
    });
    let da = attn.decompose(&gpu);
    h.run("decompose+schedule+features/attention", 200, 20, || {
        let dist = schedule(&da, &gpu);
        black_box(FeatureSet::analyze(&da, &dist, &gpu));
    });

    println!("\n== prediction engine (cache + parallel fan-out) ==");
    h.run("engine/analyze gemm (uncached)", 200, 10, || {
        // fresh engine per call: every analyze is a miss
        let e = PredictionEngine::new(16);
        black_box(e.analyze(&cfg, &gpu));
    });
    let warm = PredictionEngine::new(64);
    warm.analyze(&cfg, &gpu);
    warm.analyze(&attn, &gpu);
    h.run("engine/analyze gemm (cached)", 200, 50, || {
        black_box(warm.analyze(&cfg, &gpu));
    });
    h.run("engine/analyze attention (cached)", 200, 50, || {
        black_box(warm.analyze(&attn, &gpu));
    });
    let gpus = hw::seen_gpus();
    let ds_configs = if smoke { 4 } else { 64 };
    for threads in [1usize, 4, synperf::engine::par::default_threads()] {
        let e = PredictionEngine::new(4096);
        let t0 = std::time::Instant::now();
        let ds = e.build_dataset(KernelKind::RmsNorm, &gpus, ds_configs, 11, threads);
        println!(
            "engine/build_dataset rmsnorm {ds_configs}x{} gpus, {threads:>2} threads: {:?} ({} rows)",
            gpus.len(),
            t0.elapsed(),
            ds.len()
        );
        black_box(ds);
    }

    println!("\n== oracle testbed ==");
    let mut seed = 0u64;
    h.run("oracle/gemm", 300, 20, || {
        seed += 1;
        black_box(oracle::measure(&cfg, &gpu, seed));
    });
    h.run("oracle/attention-causal", 300, 20, || {
        seed += 1;
        black_box(oracle::measure(&attn, &gpu, seed));
    });
    h.run("dataset/make_sample (oracle+habitat+features)", 300, 10, || {
        seed += 1;
        black_box(dataset::make_sample(&cfg, &gpu, seed));
    });

    println!("\n== native MLP forward (artifact-free fallback) ==");
    let theta: Vec<f32> = (0..synperf::mlp::native::theta_size())
        .map(|i| ((i * 31 % 97) as f32 / 97.0 - 0.5) * 0.1)
        .collect();
    let mut bn = vec![0f32; synperf::mlp::native::bn_size()];
    let mut off = 0;
    for (_, fo) in &synperf::mlp::native::LAYERS[..3] {
        for v in &mut bn[off + fo..off + 2 * fo] {
            *v = 1.0;
        }
        off += 2 * fo;
    }
    let row = dataset::make_sample(&cfg, &gpu, 1).x;
    let mut scratch = synperf::mlp::native::Scratch::new();
    for b in [1usize, 256] {
        let xs = vec![row; b];
        let mut out = Vec::with_capacity(b);
        h.run(&format!("mlp/native_forward b{b}"), 200, 10, || {
            out.clear();
            synperf::mlp::native::forward_into(&theta, &bn, &xs, &mut scratch, &mut out);
            black_box(out.last().copied());
        });
    }

    println!("\n== scenario compiler (Scenario API v1) ==");
    // spec -> validated, phase-tagged op streams; no prediction work, so
    // the compiler must stay cheap enough to sweep
    let arxiv_spec = synperf::scenario::ScenarioSpec::new("Qwen2.5-14B", "A100").tp(2).seed(7);
    h.run("scenario/compile qwen2.5-14b arxiv_8 tp2", 200, 20, || {
        black_box(synperf::scenario::compile(&arxiv_spec).unwrap());
    });
    let big_spec = synperf::scenario::ScenarioSpec::new("Llama3.1-70B", "H800")
        .tp(4)
        .pp(2)
        .workload(synperf::scenario::WorkloadSpec::Sampled {
            kind: synperf::e2e::workload::WorkloadKind::Splitwise,
            batch: 32,
        })
        .seed(7);
    h.run("scenario/compile llama3.1-70b splitwise_32 tp4pp2", 200, 10, || {
        black_box(synperf::scenario::compile(&big_spec).unwrap());
    });

    service_bench(&gpu, if smoke { 64 } else { 2000 });

    println!("\n== detailed comparator costs (Fig. 7) ==");
    h.run("baseline/amali gemm-4096^3", 300, 5, || {
        black_box(synperf::baselines::amali::predict_gemm(4096, 4096, 4096, &gpu));
    });
    h.run("baseline/llmcompass gemm-4096^3", 300, 3, || {
        black_box(synperf::baselines::llmcompass::predict_gemm(4096, 4096, 4096, &gpu));
    });

    let Ok(engine) = Engine::new("artifacts") else {
        eprintln!("\n(no artifacts: skipping PJRT benches — run `make artifacts`)");
        return;
    };

    println!("\n== PJRT MLP inference ==");
    let weights = synperf::mlp::weights::ModelWeights {
        theta: engine.read_f32_blob("init_theta.bin").unwrap(),
        bn: engine.read_f32_blob("init_bn.bin").unwrap(),
        scaler: synperf::mlp::Scaler::identity(),
    };
    let pred = synperf::mlp::Predictor::new(&engine, weights).unwrap();
    for b in [1usize, 256, 1024] {
        let xs = vec![row; b];
        h.run(&format!("mlp/predict_eff b{b}"), 400, 10, || {
            black_box(pred.predict_eff(&xs).unwrap());
        });
    }
    let xs1 = vec![row; 256];
    h.run("mlp/native_forward b256 (cross-check path)", 200, 10, || {
        black_box(pred.predict_eff_native(&xs1));
    });

    println!("\n== end-to-end single prediction (Fig. 7 path) ==");
    h.run("predict/full-path gemm (features + MLP b1)", 400, 10, || {
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let f = FeatureSet::analyze(&d, &dist, &gpu);
        let x = f.to_model_input(&gpu);
        black_box(f.theory_sec / pred.predict_eff(&[x]).unwrap()[0]);
    });
}

fn service_bench(gpu: &synperf::hw::GpuSpec, n: usize) {
    println!("\n== coordinator service ==");
    let svc = PredictionService::spawn(
        synperf::api::ModelBundle::default,
        ServiceConfig::default(),
    );
    let client = svc.client();
    let t0 = std::time::Instant::now();
    // blocking submits: the bounded queue applies backpressure while the
    // service drains, instead of accumulating an unbounded backlog
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            client
                .submit(synperf::api::PredictRequest::new(
                    KernelConfig::RmsNorm { seq: 128 + (i % 64) as u32, dim: 4096 },
                    gpu.clone(),
                ))
                .unwrap()
        })
        .collect();
    for p in pendings {
        p.wait().unwrap();
    }
    let wall = t0.elapsed();
    let snap = svc.metrics.snapshot();
    println!(
        "service: {n} reqs in {wall:?} = {:.0} req/s (mean batch {:.1})",
        n as f64 / wall.as_secs_f64(),
        snap.mean_batch
    );
    svc.shutdown();
}
