//! `cargo bench --bench hot_paths` — microbenchmarks of the Layer-3 request
//! path (hand-rolled harness; criterion is not in the offline vendor set):
//!
//!   decompose -> schedule -> features   (the analytical front half)
//!   sharded cache under contention      (8 threads, shards 1 vs 16)
//!   oracle measurement                  (dataset generation throughput)
//!   scenario compile                    (ScenarioSpec -> phase-tagged op streams)
//!   scenario evaluate                   (two-pass parallel, 1 vs 8 threads)
//!   sweep grid expand + run             (fleet search: points/sec, 2 vs 4 workers)
//!   autotune run                        (§VII ceiling-guided search, 1 vs 8 workers)
//!   protocol batch routing              (predictions/sec through api::predict_batch)
//!   native MLP forward                  (artifact-free fallback path, serial + par
//!                                        + AVX2 f32x8 vs scalar reference)
//!   MLP forward via PJRT (b1 / b256 / b1024)
//!   end-to-end single prediction       (the Fig. 7 "SynPerf time" path)
//!   coordinator service throughput
//!   tcp serving front end              (8 pipelined JSONL connections)
//!
//! Flags (after `--`):
//!   --json <path>   also write results as JSON (BENCH_PR*.json schema)
//!   --smoke         minimal iteration counts — CI smoke so the binary
//!                   can't rot; timings are NOT meaningful in this mode
//!                   (also enabled by SYNPERF_BENCH_SMOKE=1)

use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::dataset;
use synperf::engine::PredictionEngine;
use synperf::features::FeatureSet;
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::oracle;
use synperf::runtime::Engine;
use synperf::sched::schedule;
use synperf::util::argp::Args;
use synperf::util::bench::{bench, black_box, write_json, BenchResult};

struct Harness {
    smoke: bool,
    results: Vec<BenchResult>,
}

impl Harness {
    fn run(&mut self, name: &str, budget_ms: u64, min_iters: usize, f: impl FnMut()) {
        let (budget_ms, min_iters) = if self.smoke { (1, 2) } else { (budget_ms, min_iters) };
        let r = bench(name, budget_ms, min_iters, f);
        println!("{}", r.report());
        self.results.push(r);
    }
}

fn main() {
    // cargo passes a bare `--bench` to bench binaries; Args absorbs it as a
    // switch, so only our own flags matter here
    let args = Args::parse(std::env::args().skip(1));
    let smoke = args.has("smoke")
        || std::env::var("SYNPERF_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let mut h = Harness { smoke, results: Vec::new() };

    run_benches(&mut h, smoke);

    if let Some(path) = args.str_opt("json") {
        write_json(path, &h.results).expect("write bench json");
        println!("\nwrote {} bench results to {path}", h.results.len());
    }
}

fn run_benches(h: &mut Harness, smoke: bool) {
    let gpu = hw::gpu_by_name("A100").unwrap();
    let cfg = KernelConfig::Gemm { m: 4096, n: 11008, k: 4096, dtype: DType::Bf16 };
    let attn = KernelConfig::Attention {
        batch: vec![(2048, 2048); 8],
        nh: 32,
        nkv: 8,
        hd: 128,
        causal: true,
        fa3: false,
    };

    println!("== analytical front half ==");
    // the two perf-acceptance configs: full decompose -> schedule ->
    // features chain (grouped closed form: O(groups + num_sms))
    h.run("dsf/gemm-4096x11008x4096", 300, 20, || {
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        black_box(FeatureSet::analyze(&d, &dist, &gpu));
    });
    h.run("dsf/attention-8x2048-causal", 300, 20, || {
        let d = attn.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        black_box(FeatureSet::analyze(&d, &dist, &gpu));
    });
    h.run("decompose/gemm-4096x11008x4096", 200, 20, || {
        black_box(cfg.decompose(&gpu));
    });
    let d = cfg.decompose(&gpu);
    h.run("schedule/hardware-rr", 200, 20, || {
        black_box(schedule(&d, &gpu));
    });
    let dist = schedule(&d, &gpu);
    h.run("features/analyze", 200, 20, || {
        black_box(FeatureSet::analyze(&d, &dist, &gpu));
    });
    let da = attn.decompose(&gpu);
    h.run("decompose+schedule+features/attention", 200, 20, || {
        let dist = schedule(&da, &gpu);
        black_box(FeatureSet::analyze(&da, &dist, &gpu));
    });

    println!("\n== prediction engine (cache + parallel fan-out) ==");
    h.run("engine/analyze gemm (uncached)", 200, 10, || {
        // fresh engine per call: every analyze is a miss
        let e = PredictionEngine::new(16);
        black_box(e.analyze(&cfg, &gpu));
    });
    let warm = PredictionEngine::new(64);
    warm.analyze(&cfg, &gpu);
    warm.analyze(&attn, &gpu);
    h.run("engine/analyze gemm (cached)", 200, 50, || {
        black_box(warm.analyze(&cfg, &gpu));
    });
    h.run("engine/analyze attention (cached)", 200, 50, || {
        black_box(warm.analyze(&attn, &gpu));
    });
    let gpus = hw::seen_gpus();
    let ds_configs = if smoke { 4 } else { 64 };
    for threads in [1usize, 4, synperf::engine::par::default_threads()] {
        let e = PredictionEngine::new(4096);
        let t0 = std::time::Instant::now();
        let ds = e.build_dataset(KernelKind::RmsNorm, &gpus, ds_configs, 11, threads);
        println!(
            "engine/build_dataset rmsnorm {ds_configs}x{} gpus, {threads:>2} threads: {:?} ({} rows)",
            gpus.len(),
            t0.elapsed(),
            ds.len()
        );
        black_box(ds);
    }

    println!("\n== sharded cache under contention ==");
    // 8 threads hammering a fully hot cache: with one shard every lookup
    // serializes on the single mutex (the pre-shard baseline); with 16
    // shards concurrent probes collide only when their probe hashes share
    // low bits. The sharded variant must win on >= 2 threads.
    let hot_cfgs: Vec<KernelConfig> = (0..64u32)
        .map(|i| KernelConfig::RmsNorm { seq: 256 + i, dim: 4096 })
        .collect();
    for shards in [1usize, 16] {
        let e = PredictionEngine::with_shards(4096, shards);
        for c in &hot_cfgs {
            e.analyze(c, &gpu);
        }
        h.run(&format!("engine/analyze-contended 8thr shards{shards}"), 300, 5, || {
            std::thread::scope(|s| {
                for t in 0..8usize {
                    let (e, hot_cfgs, gpu) = (&e, &hot_cfgs, &gpu);
                    s.spawn(move || {
                        for i in 0..200usize {
                            let c = &hot_cfgs[(i * 7 + t * 13) % hot_cfgs.len()];
                            black_box(e.analyze(c, gpu));
                        }
                    });
                }
            });
        });
    }

    println!("\n== oracle testbed ==");
    let mut seed = 0u64;
    h.run("oracle/gemm", 300, 20, || {
        seed += 1;
        black_box(oracle::measure(&cfg, &gpu, seed));
    });
    h.run("oracle/attention-causal", 300, 20, || {
        seed += 1;
        black_box(oracle::measure(&attn, &gpu, seed));
    });
    h.run("dataset/make_sample (oracle+habitat+features)", 300, 10, || {
        seed += 1;
        black_box(dataset::make_sample(&cfg, &gpu, seed));
    });

    println!("\n== native MLP forward (artifact-free fallback) ==");
    let theta: Vec<f32> = (0..synperf::mlp::native::theta_size())
        .map(|i| ((i * 31 % 97) as f32 / 97.0 - 0.5) * 0.1)
        .collect();
    let mut bn = vec![0f32; synperf::mlp::native::bn_size()];
    let mut off = 0;
    for (_, fo) in &synperf::mlp::native::LAYERS[..3] {
        for v in &mut bn[off + fo..off + 2 * fo] {
            *v = 1.0;
        }
        off += 2 * fo;
    }
    let row = dataset::make_sample(&cfg, &gpu, 1).x;
    let mut scratch = synperf::mlp::native::Scratch::new();
    for b in [1usize, 256] {
        let xs = vec![row; b];
        let mut out = Vec::with_capacity(b);
        h.run(&format!("mlp/native_forward b{b}"), 200, 10, || {
            out.clear();
            synperf::mlp::native::forward_into(&theta, &bn, &xs, &mut scratch, &mut out);
            black_box(out.last().copied());
        });
    }
    // AVX2 f32x8 fast path against the always-compiled scalar reference
    // (pinned bit-identical in mlp::native's tests) — forward_into above
    // already picks the fast path at runtime; this pair isolates the win
    if synperf::mlp::native::simd_available() {
        let xs = vec![row; 256];
        let mut out = Vec::with_capacity(256);
        for (simd, name) in [(false, "scalar"), (true, "simd")] {
            h.run(&format!("mlp/native_forward_{name} b256"), 200, 10, || {
                out.clear();
                synperf::mlp::native::forward_into_with(
                    simd, &theta, &bn, &xs, &mut scratch, &mut out,
                );
                black_box(out.last().copied());
            });
        }
    } else {
        println!("(no AVX2 on this CPU: skipping mlp/native_forward_simd)");
    }
    // chunked parallel forward with one thread-local Scratch per worker
    // (bit-identical to the serial path at any thread count)
    let xs_par = vec![row; 1024];
    for threads in [1usize, 8] {
        h.run(&format!("mlp/native_forward_par b1024 t{threads}"), 200, 5, || {
            black_box(synperf::mlp::native::forward_par(&theta, &bn, &xs_par, threads));
        });
    }

    println!("\n== scenario compiler (Scenario API v1) ==");
    // spec -> validated, phase-tagged op streams; no prediction work, so
    // the compiler must stay cheap enough to sweep
    let arxiv_spec = synperf::scenario::ScenarioSpec::new("Qwen2.5-14B", "A100").tp(2).seed(7);
    h.run("scenario/compile qwen2.5-14b arxiv_8 tp2", 200, 20, || {
        black_box(synperf::scenario::compile(&arxiv_spec).unwrap());
    });
    let big_spec = synperf::scenario::ScenarioSpec::new("Llama3.1-70B", "H800")
        .tp(4)
        .pp(2)
        .workload(synperf::scenario::WorkloadSpec::Sampled {
            kind: synperf::e2e::workload::WorkloadKind::Splitwise,
            batch: 32,
        })
        .seed(7);
    h.run("scenario/compile llama3.1-70b splitwise_32 tp4pp2", 200, 10, || {
        black_box(synperf::scenario::compile(&big_spec).unwrap());
    });

    println!("\n== scenario evaluator (two-pass deterministic parallel) ==");
    // full compile -> parallel per-item pass -> serial accumulation ->
    // batched routing, degraded mode: wall clock scales with threads while
    // the report stays bit-identical (pinned in tests/concurrency.rs)
    let eval_spec = synperf::scenario::ScenarioSpec::new("Qwen2.5-14B", "A100")
        .tp(2)
        .workload(synperf::scenario::WorkloadSpec::Explicit(vec![
            synperf::e2e::workload::Request { input_len: 256, output_len: 32 },
            synperf::e2e::workload::Request { input_len: 128, output_len: 16 },
        ]))
        .seed(7);
    for threads in [1usize, 8] {
        let sim = synperf::scenario::Simulator::degraded().threads(threads);
        h.run(&format!("scenario/evaluate-{threads}thread"), 400, 3, || {
            black_box(sim.simulate(&eval_spec).unwrap());
        });
    }

    println!("\n== cluster simulator (Scenario v2) ==");
    // seeded Poisson arrivals through continuous batching on two replicas;
    // the event loop is serial, threads only fan out the per-step batch
    // prediction, so the report is byte-identical at any thread count
    // (events/sec = report.events / median)
    let cluster_n = if smoke { 8 } else { 64 };
    let cluster_spec = synperf::scenario::ClusterSpec::new("Llama3.1-8B", "A100")
        .replicas(2)
        .arrivals(synperf::scenario::ArrivalSpec::Poisson {
            rate_rps: 32.0,
            n: cluster_n,
            kind: synperf::e2e::workload::WorkloadKind::Arxiv,
        })
        .max_batch(8)
        .kv_capacity_tokens(1 << 17)
        .seed(7);
    let cluster_sim = synperf::scenario::Simulator::degraded();
    let mut cluster_events = 0u64;
    for threads in [1usize, 8] {
        h.run(&format!("scenario/cluster-sim-{threads}thread n{cluster_n}"), 400, 3, || {
            let r = cluster_sim.simulate_cluster_with_threads(&cluster_spec, threads).unwrap();
            cluster_events = r.events;
            black_box(r);
        });
        if let Some(r) = h.results.last() {
            println!(
                "  -> {:.0} events/sec at the median ({cluster_events} events)",
                cluster_events as f64 / (r.median_ns * 1e-9)
            );
        }
    }

    println!("\n== sweep grid (fleet-scale hardware search) ==");
    // the 88-point acceptance grid — whole registry x tp {1,2} x replicas
    // {1,2} x 2 workloads; expand is pure validation + cross-product, so
    // it must stay negligible next to evaluating even one grid point
    let chat = synperf::scenario::ScenarioSpec::new("Llama3.1-8B", "A100").workload(
        synperf::scenario::WorkloadSpec::Explicit(vec![synperf::e2e::workload::Request {
            input_len: 64,
            output_len: 4,
        }]),
    );
    let long = synperf::scenario::ScenarioSpec::new("Llama3.1-8B", "A100")
        .workload(synperf::scenario::WorkloadSpec::Explicit(vec![
            synperf::e2e::workload::Request { input_len: 96, output_len: 8 },
        ]))
        .seed(5);
    let grid_spec = synperf::sweep::SweepSpec::new()
        .tp(vec![1, 2])
        .replicas(vec![1, 2])
        .scenario("chat", chat.clone())
        .scenario("long", long);
    let grid_points = synperf::sweep::expand(&grid_spec).unwrap().len();
    h.run(&format!("sweep/grid expand {grid_points}pt"), 200, 20, || {
        black_box(synperf::sweep::expand(&grid_spec).unwrap());
    });
    if let Some(r) = h.results.last() {
        println!(
            "  -> {:.0} points/sec at the median",
            grid_points as f64 / (r.median_ns * 1e-9)
        );
    }
    // a sweep end to end: work-stealing workers with per-worker simulators
    // over a 4-point grid; rows are byte-identical at any thread count
    // (pinned in src/sweep/runner.rs), so threads is a wall-clock-only knob
    let run_spec = synperf::sweep::SweepSpec::new()
        .gpus(synperf::sweep::GpuFilter::Named(vec!["A100".into(), "H800".into()]))
        .tp(vec![1, 2])
        .scenario("chat", chat);
    for threads in [2usize, 4] {
        h.run(&format!("sweep/run 4pt {threads}thread"), 300, 3, || {
            black_box(
                synperf::sweep::run_sweep(
                    &run_spec,
                    synperf::scenario::Simulator::degraded,
                    threads,
                    |_| {},
                )
                .unwrap(),
            );
        });
    }
    // the crash-safety tax: one fsync'd journal line per completed row
    // (write_all + sync_data) — this append rate is the floor under any
    // journaled sweep, so it must stay far above the points/sec above
    let journal_path =
        std::env::temp_dir().join(format!("synperf_bench_journal_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&journal_path);
    let mut row_line = String::new();
    synperf::sweep::run_sweep(&run_spec, synperf::scenario::Simulator::degraded, 1, |r| {
        if row_line.is_empty() {
            row_line = synperf::sweep::wire::encode_row(r);
        }
    })
    .unwrap();
    let mut session = synperf::sweep::JournalSession::open(
        &journal_path,
        &run_spec,
        synperf::sweep::Shard::default(),
        false,
    )
    .unwrap();
    h.run("sweep/journal append", 200, 10, || {
        session.record(black_box(&row_line)).unwrap();
    });
    drop(session);
    let _ = std::fs::remove_file(&journal_path);

    println!("\n== autotune (§VII ceiling-guided kernel search) ==");
    // diagnose + brute-force tune 3 sampled fused-MoE launches on one GPU
    // with one Ceiling per worker; rows are byte-identical at any thread
    // count (pinned in src/autotune/search.rs), so threads is a
    // wall-clock-only knob
    let tune_spec = synperf::autotune::TuneSpec::new()
        .gpus(synperf::sweep::GpuFilter::Named(vec!["A40".into()]))
        .source(synperf::autotune::ConfigSource::Sampled { n: 3 })
        .seed(31);
    for threads in [1usize, 8] {
        h.run(&format!("autotune/tune 3pt {threads}thread"), 300, 3, || {
            black_box(
                synperf::autotune::run_tune(
                    &tune_spec,
                    synperf::autotune::Ceiling::auto,
                    threads,
                    |_| {},
                )
                .unwrap(),
            );
        });
    }

    println!("\n== protocol batch routing ==");
    // the serving-scale unit of work: one typed batch through the one
    // request path on a hot cache (predictions/sec = 256 / median)
    let bundle = synperf::api::ModelBundle::default();
    let preqs: Vec<synperf::api::PredictRequest> = (0..256u32)
        .map(|i| {
            synperf::api::PredictRequest::new(
                KernelConfig::RmsNorm { seq: 512 + (i % 32), dim: 4096 },
                gpu.clone(),
            )
        })
        .collect();
    black_box(synperf::api::predict_batch(&bundle, &preqs)); // warm the cache
    h.run("api/predict_batch b256 (hot cache)", 300, 10, || {
        black_box(synperf::api::predict_batch(&bundle, &preqs));
    });
    if let Some(r) = h.results.last() {
        println!("  -> {:.0} predictions/sec at the median", 256.0 / (r.median_ns * 1e-9));
    }

    service_bench(&gpu, if smoke { 64 } else { 2000 });

    tcp_bench(h, if smoke { 8 } else { 64 });

    println!("\n== detailed comparator costs (Fig. 7) ==");
    h.run("baseline/amali gemm-4096^3", 300, 5, || {
        black_box(synperf::baselines::amali::predict_gemm(4096, 4096, 4096, &gpu));
    });
    h.run("baseline/llmcompass gemm-4096^3", 300, 3, || {
        black_box(synperf::baselines::llmcompass::predict_gemm(4096, 4096, 4096, &gpu));
    });

    let Ok(engine) = Engine::new("artifacts") else {
        eprintln!("\n(no artifacts: skipping PJRT benches — run `make artifacts`)");
        return;
    };

    println!("\n== PJRT MLP inference ==");
    let weights = synperf::mlp::weights::ModelWeights {
        theta: engine.read_f32_blob("init_theta.bin").unwrap(),
        bn: engine.read_f32_blob("init_bn.bin").unwrap(),
        scaler: synperf::mlp::Scaler::identity(),
    };
    let pred = synperf::mlp::Predictor::new(&engine, weights).unwrap();
    for b in [1usize, 256, 1024] {
        let xs = vec![row; b];
        h.run(&format!("mlp/predict_eff b{b}"), 400, 10, || {
            black_box(pred.predict_eff(&xs).unwrap());
        });
    }
    let xs1 = vec![row; 256];
    // threads = 1 keeps this the *serial* cross-check path, comparable to
    // the BENCH_PR3 numbers (predict_eff_native would auto-parallelize a
    // 256-row batch); the parallel variant is benched above as
    // mlp/native_forward_par
    h.run("mlp/native_forward b256 (cross-check path)", 200, 10, || {
        black_box(pred.predict_eff_native_threads(&xs1, 1));
    });

    println!("\n== end-to-end single prediction (Fig. 7 path) ==");
    h.run("predict/full-path gemm (features + MLP b1)", 400, 10, || {
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let f = FeatureSet::analyze(&d, &dist, &gpu);
        let x = f.to_model_input(&gpu);
        black_box(f.theory_sec / pred.predict_eff(&[x]).unwrap()[0]);
    });
}

fn tcp_bench(h: &mut Harness, per_client: usize) {
    println!("\n== tcp serving front end ==");
    use std::io::{BufRead, BufReader, BufWriter, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    use synperf::api::tcp::{self, TcpConfig};
    const CLIENTS: usize = 8;
    let svc = PredictionService::spawn(
        synperf::api::ModelBundle::default,
        ServiceConfig::default(),
    );
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = svc.client();
    let cfg = TcpConfig::default();
    let shutdown = AtomicBool::new(false);
    std::thread::scope(|s| {
        let server = s.spawn(|| {
            tcp::serve(
                listener,
                &client,
                synperf::scenario::Simulator::degraded,
                &cfg,
                &shutdown,
            )
            .unwrap()
        });
        // one iteration = 8 fresh connections, each pipelining
        // `per_client` predict lines and reading every response — the
        // full read -> classify -> admit -> batch -> encode path,
        // connection setup included
        h.run(&format!("tcp/serve-8client x{per_client}"), 500, 3, || {
            std::thread::scope(|conns| {
                for c in 0..CLIENTS {
                    conns.spawn(move || {
                        let stream = std::net::TcpStream::connect(addr).unwrap();
                        let mut w = BufWriter::new(stream.try_clone().unwrap());
                        for j in 0..per_client {
                            writeln!(
                                w,
                                "{{\"id\":\"b{c}-{j}\",\"gpu\":\"A100\",\"kernel\":\
                                 {{\"type\":\"rmsnorm\",\"seq\":{},\"dim\":4096}}}}",
                                512 + (j % 32)
                            )
                            .unwrap();
                        }
                        w.flush().unwrap();
                        let mut r = BufReader::new(stream);
                        let mut line = String::new();
                        for _ in 0..per_client {
                            line.clear();
                            assert!(r.read_line(&mut line).unwrap() > 0, "early EOF");
                        }
                    });
                }
            });
        });
        if let Some(r) = h.results.last() {
            println!(
                "  -> {:.0} req/s at the median across {CLIENTS} connections",
                (CLIENTS * per_client) as f64 / (r.median_ns * 1e-9)
            );
        }
        shutdown.store(true, Ordering::Release);
        let stats = server.join().unwrap();
        assert_eq!(stats.errors, 0, "tcp bench must serve clean: {stats:?}");
    });
    svc.shutdown();
}

fn service_bench(gpu: &synperf::hw::GpuSpec, n: usize) {
    println!("\n== coordinator service ==");
    let svc = PredictionService::spawn(
        synperf::api::ModelBundle::default,
        ServiceConfig::default(),
    );
    let client = svc.client();
    let t0 = std::time::Instant::now();
    // blocking submits: the bounded queue applies backpressure while the
    // service drains, instead of accumulating an unbounded backlog
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            client
                .submit(synperf::api::PredictRequest::new(
                    KernelConfig::RmsNorm { seq: 128 + (i % 64) as u32, dim: 4096 },
                    gpu.clone(),
                ))
                .unwrap()
        })
        .collect();
    for p in pendings {
        p.wait().unwrap();
    }
    let wall = t0.elapsed();
    let snap = svc.metrics.snapshot();
    println!(
        "service: {n} reqs in {wall:?} = {:.0} req/s (mean batch {:.1})",
        n as f64 / wall.as_secs_f64(),
        snap.mean_batch
    );
    svc.shutdown();
}
