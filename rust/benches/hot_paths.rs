//! `cargo bench --bench hot_paths` — microbenchmarks of the Layer-3 request
//! path (hand-rolled harness; criterion is not in the offline vendor set):
//!
//!   decompose -> schedule -> features   (the analytical front half)
//!   oracle measurement                  (dataset generation throughput)
//!   MLP forward via PJRT (b1 / b256 / b1024)
//!   end-to-end single prediction       (the Fig. 7 "SynPerf time" path)
//!   coordinator service throughput

use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::dataset;
use synperf::engine::PredictionEngine;
use synperf::features::FeatureSet;
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::oracle;
use synperf::runtime::Engine;
use synperf::sched::schedule;
use synperf::util::bench::{bench, black_box};

fn main() {
    let gpu = hw::gpu_by_name("A100").unwrap();
    let cfg = KernelConfig::Gemm { m: 4096, n: 11008, k: 4096, dtype: DType::Bf16 };
    let attn = KernelConfig::Attention {
        batch: vec![(2048, 2048); 8],
        nh: 32,
        nkv: 8,
        hd: 128,
        causal: true,
        fa3: false,
    };

    println!("== analytical front half ==");
    let r = bench("decompose/gemm-4096x11008x4096", 200, 20, || {
        black_box(cfg.decompose(&gpu));
    });
    println!("{}", r.report());
    let d = cfg.decompose(&gpu);
    let r = bench("schedule/hardware-rr", 200, 20, || {
        black_box(schedule(&d, &gpu));
    });
    println!("{}", r.report());
    let dist = schedule(&d, &gpu);
    let r = bench("features/analyze", 200, 20, || {
        black_box(FeatureSet::analyze(&d, &dist, &gpu));
    });
    println!("{}", r.report());
    let da = attn.decompose(&gpu);
    let r = bench("decompose+schedule+features/attention", 200, 20, || {
        let dist = schedule(&da, &gpu);
        black_box(FeatureSet::analyze(&da, &dist, &gpu));
    });
    println!("{}", r.report());

    println!("\n== prediction engine (cache + parallel fan-out) ==");
    let r = bench("engine/analyze gemm (uncached)", 200, 10, || {
        // fresh engine per call: every analyze is a miss
        let e = PredictionEngine::new(16);
        black_box(e.analyze(&cfg, &gpu));
    });
    println!("{}", r.report());
    let warm = PredictionEngine::new(64);
    warm.analyze(&cfg, &gpu);
    warm.analyze(&attn, &gpu);
    let r = bench("engine/analyze gemm (cached)", 200, 50, || {
        black_box(warm.analyze(&cfg, &gpu));
    });
    println!("{}", r.report());
    let r = bench("engine/analyze attention (cached)", 200, 50, || {
        black_box(warm.analyze(&attn, &gpu));
    });
    println!("{}", r.report());
    let gpus = hw::seen_gpus();
    for threads in [1usize, 4, synperf::engine::par::default_threads()] {
        let e = PredictionEngine::new(4096);
        let t0 = std::time::Instant::now();
        let ds = e.build_dataset(KernelKind::RmsNorm, &gpus, 64, 11, threads);
        println!(
            "engine/build_dataset rmsnorm 64x{} gpus, {threads:>2} threads: {:?} ({} rows)",
            gpus.len(),
            t0.elapsed(),
            ds.len()
        );
        black_box(ds);
    }

    println!("\n== oracle testbed ==");
    let mut seed = 0u64;
    let r = bench("oracle/gemm", 300, 20, || {
        seed += 1;
        black_box(oracle::measure(&cfg, &gpu, seed));
    });
    println!("{}", r.report());
    let r = bench("oracle/attention-causal", 300, 20, || {
        seed += 1;
        black_box(oracle::measure(&attn, &gpu, seed));
    });
    println!("{}", r.report());
    let r = bench("dataset/make_sample (oracle+habitat+features)", 300, 10, || {
        seed += 1;
        black_box(dataset::make_sample(&cfg, &gpu, seed));
    });
    println!("{}", r.report());

    let Ok(engine) = Engine::new("artifacts") else {
        eprintln!("\n(no artifacts: skipping PJRT benches — run `make artifacts`)");
        return;
    };

    println!("\n== PJRT MLP inference ==");
    let weights = synperf::mlp::weights::ModelWeights {
        theta: engine.read_f32_blob("init_theta.bin").unwrap(),
        bn: engine.read_f32_blob("init_bn.bin").unwrap(),
        scaler: synperf::mlp::Scaler::identity(),
    };
    let pred = synperf::mlp::Predictor::new(&engine, weights).unwrap();
    let row = dataset::make_sample(&cfg, &gpu, 1).x;
    for b in [1usize, 256, 1024] {
        let xs = vec![row; b];
        let r = bench(&format!("mlp/predict_eff b{b}"), 400, 10, || {
            black_box(pred.predict_eff(&xs).unwrap());
        });
        println!("{}  ({:.2} us/row)", r.report(), r.median_ns / 1e3 / b as f64);
    }
    let xs1 = vec![row; 256];
    let r = bench("mlp/native_forward b256 (cross-check path)", 200, 10, || {
        black_box(pred.predict_eff_native(&xs1));
    });
    println!("{}", r.report());

    println!("\n== end-to-end single prediction (Fig. 7 path) ==");
    let r = bench("predict/full-path gemm (features + MLP b1)", 400, 10, || {
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let f = FeatureSet::analyze(&d, &dist, &gpu);
        let x = f.to_model_input(&gpu);
        black_box(f.theory_sec / pred.predict_eff(&[x]).unwrap()[0]);
    });
    println!("{}", r.report());

    println!("\n== coordinator service ==");
    let svc = PredictionService::spawn(
        synperf::api::ModelBundle::default,
        ServiceConfig::default(),
    );
    let client = svc.client();
    let t0 = std::time::Instant::now();
    let n = 2000;
    // blocking submits: the bounded queue applies backpressure while the
    // service drains, instead of accumulating an unbounded backlog
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            client
                .submit(synperf::api::PredictRequest::new(
                    KernelConfig::RmsNorm { seq: 128 + (i % 64) as u32, dim: 4096 },
                    gpu.clone(),
                ))
                .unwrap()
        })
        .collect();
    for p in pendings {
        p.wait().unwrap();
    }
    let wall = t0.elapsed();
    let snap = svc.metrics.snapshot();
    println!(
        "service: {n} reqs in {wall:?} = {:.0} req/s (mean batch {:.1})",
        n as f64 / wall.as_secs_f64(),
        snap.mean_batch
    );
    svc.shutdown();

    println!("\n== detailed comparator costs (Fig. 7) ==");
    let r = bench("baseline/amali gemm-4096^3", 300, 5, || {
        black_box(synperf::baselines::amali::predict_gemm(4096, 4096, 4096, &gpu));
    });
    println!("{}", r.report());
    let r = bench("baseline/llmcompass gemm-4096^3", 300, 3, || {
        black_box(synperf::baselines::llmcompass::predict_gemm(4096, 4096, 4096, &gpu));
    });
    println!("{}", r.report());
}
