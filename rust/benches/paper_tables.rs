//! `cargo bench --bench paper_tables` — regenerates every paper table and
//! figure (DESIGN.md §5) at the fast scale and times each experiment.
//! This is the deliverable-(d) harness: one bench per table/figure, printing
//! the same rows/series the paper reports.
//!
//! Requires `make artifacts`; experiments cache datasets/models in runs/.

use synperf::experiments::{run, Lab, Scale};

fn main() {
    let lab = match Lab::new(Scale::Fast) {
        Ok(lab) => lab,
        Err(e) => {
            eprintln!("skipping paper_tables bench (no artifacts): {e:#}");
            return;
        }
    };
    let ids = [
        "table1", "table7", "fig3", "fig4", "fig5", "scaledmm", "fig7", "fig6", "table9",
        "fig8",
    ];
    let mut failures = 0;
    for id in ids {
        let t0 = std::time::Instant::now();
        match run(&lab, id) {
            Ok(_) => println!("[bench] {id:<8} regenerated in {:?}\n", t0.elapsed()),
            Err(e) => {
                failures += 1;
                eprintln!("[bench] {id:<8} FAILED: {e:#}\n");
            }
        }
    }
    assert_eq!(failures, 0, "{failures} experiment benches failed");
    println!("[bench] all paper tables/figures regenerated; see runs/results.txt");
}
