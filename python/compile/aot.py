"""AOT compile path: lower the Layer-2 MLP (with its Layer-1 Pallas kernels)
to HLO *text* artifacts consumed by the rust PJRT runtime.

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; python never executes on the request path.

Outputs (in artifacts/):
  mlp_fwd_b{B}.hlo.txt        (theta, bn, x[B,F])                -> (eff[B],)
  mlp_train_mape_b{B}.hlo.txt (theta,m,v,bn,x,y,step,key) -> (theta',m',v',bn',loss)
  mlp_train_p80_b{B}.hlo.txt  same with pinball(tau=0.8) loss
  init_theta.bin / init_bn.bin  initial parameter blobs (f32 LE)
  manifest.json               packing + arg-order contract for rust
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

FWD_BATCHES = (1, 64, 256, 1024)
TRAIN_BATCH = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_fwd(batch: int) -> str:
    fn = lambda theta, bn, x: (model.predict(theta, bn, x),)
    lowered = jax.jit(fn).lower(
        _spec((model.THETA_SIZE,)),
        _spec((model.BN_SIZE,)),
        _spec((batch, model.FEATURE_DIM)),
    )
    return to_hlo_text(lowered)


def lower_train(batch: int, tau) -> str:
    fn = functools.partial(model.train_step, tau=tau)
    lowered = jax.jit(fn).lower(
        _spec((model.THETA_SIZE,)),          # theta
        _spec((model.THETA_SIZE,)),          # m
        _spec((model.THETA_SIZE,)),          # v
        _spec((model.BN_SIZE,)),             # bn
        _spec((batch, model.FEATURE_DIM)),   # x
        _spec((batch,)),                     # y
        _spec(()),                           # step (f32, 1-based)
        _spec((2,), jnp.uint32),             # PRNG key
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    def write(name: str, text: str) -> None:
        path = os.path.join(out, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {name} ({len(text) // 1024} KiB)")

    print("[aot] lowering forward variants")
    for b in FWD_BATCHES:
        write(f"mlp_fwd_b{b}.hlo.txt", lower_fwd(b))

    print("[aot] lowering train steps (MAPE + P80 pinball)")
    write(f"mlp_train_mape_b{TRAIN_BATCH}.hlo.txt", lower_train(TRAIN_BATCH, None))
    write(f"mlp_train_p80_b{TRAIN_BATCH}.hlo.txt", lower_train(TRAIN_BATCH, 0.8))

    print("[aot] dumping initial parameter blobs")
    theta = model.init_theta(jax.random.PRNGKey(0))
    bn = model.init_bn()
    with open(os.path.join(out, "init_theta.bin"), "wb") as f:
        f.write(bytes(memoryview(jnp.asarray(theta, jnp.float32)).cast("B")))
    with open(os.path.join(out, "init_bn.bin"), "wb") as f:
        f.write(bytes(memoryview(jnp.asarray(bn, jnp.float32)).cast("B")))

    manifest = {
        "feature_dim": model.FEATURE_DIM,
        "layers": model.LAYERS,
        "theta_size": int(model.THETA_SIZE),
        "bn_size": int(model.BN_SIZE),
        "fwd_batches": list(FWD_BATCHES),
        "train_batch": TRAIN_BATCH,
        "fwd_args": ["theta", "bn", "x"],
        "fwd_outs": ["eff"],
        "train_args": ["theta", "m", "v", "bn", "x", "y", "step", "key"],
        "train_outs": ["theta", "m", "v", "bn", "loss"],
        "lr": model.LR,
        "weight_decay": model.WD,
        "dropout": model.DROPOUT,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("[aot] done")


if __name__ == "__main__":
    main()
