"""Layer-2 JAX model: the SynPerf performance-estimator MLP.

Architecture (paper §V-C): 3 hidden layers (256, 128, 64), each
dense -> ReLU -> BatchNorm -> Dropout(0.1); sigmoid output head predicting
*execution efficiency* in [0, 1].  Dense layers are the Layer-1 Pallas
kernels (kernels/mlp.py); everything else is cheap elementwise jnp.

Two training objectives are exported (§V-C and §VII-A):
  * MAPE loss        — the accuracy model (latency = theory / efficiency)
  * pinball loss τ=.8 — the P80 "potential performance ceiling" model

All trainable parameters live in one flat ``theta[P]`` vector and all
BatchNorm running statistics in one flat ``bn[S]`` vector so the rust
runtime only ever moves opaque blobs; the packing is mirrored into
``artifacts/manifest.json`` by aot.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.mlp import fused_dense

FEATURE_DIM = 32
HIDDEN = (256, 128, 64)
LAYERS = [(FEATURE_DIM, HIDDEN[0]), (HIDDEN[0], HIDDEN[1]),
          (HIDDEN[1], HIDDEN[2]), (HIDDEN[2], 1)]
DROPOUT = 0.1
BN_MOMENTUM = 0.1
BN_EPS = 1e-5
# AdamW hyper-parameters (paper: AdamW, lr=1e-3, weight decay).
LR = 1e-3
WD = 1e-4
BETA1, BETA2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# Flat parameter packing.
# ---------------------------------------------------------------------------

def _param_shapes():
    """(name, shape) list defining the theta layout, in packing order."""
    shapes = []
    for li, (fan_in, fan_out) in enumerate(LAYERS):
        shapes.append((f"w{li}", (fan_in, fan_out)))
        shapes.append((f"b{li}", (fan_out,)))
        if li < len(LAYERS) - 1:  # hidden layers carry BatchNorm affine
            shapes.append((f"gamma{li}", (fan_out,)))
            shapes.append((f"beta{li}", (fan_out,)))
    return shapes


def _bn_shapes():
    shapes = []
    for li in range(len(LAYERS) - 1):
        n = LAYERS[li][1]
        shapes.append((f"mu{li}", (n,)))
        shapes.append((f"var{li}", (n,)))
    return shapes


def _size(shapes):
    return sum(int(jnp.prod(jnp.array(s))) for _, s in shapes)


THETA_SIZE = _size(_param_shapes())
BN_SIZE = _size(_bn_shapes())


def _unpack(flat, shapes):
    out, off = {}, 0
    for name, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        out[name] = flat[off:off + n].reshape(shape)
        off += n
    return out


def _pack(tree, shapes):
    return jnp.concatenate([tree[name].reshape(-1) for name, _ in shapes])


def unpack_theta(theta):
    return _unpack(theta, _param_shapes())


def pack_theta(params):
    return _pack(params, _param_shapes())


def unpack_bn(bn):
    return _unpack(bn, _bn_shapes())


def pack_bn(state):
    return _pack(state, _bn_shapes())


def init_theta(key) -> jax.Array:
    """He-init weights, zero biases, identity BatchNorm affine."""
    params = {}
    for li, (fan_in, fan_out) in enumerate(LAYERS):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / fan_in)
        params[f"w{li}"] = scale * jax.random.normal(
            sub, (fan_in, fan_out), jnp.float32)
        params[f"b{li}"] = jnp.zeros((fan_out,), jnp.float32)
        if li < len(LAYERS) - 1:
            params[f"gamma{li}"] = jnp.ones((fan_out,), jnp.float32)
            params[f"beta{li}"] = jnp.zeros((fan_out,), jnp.float32)
    return pack_theta(params)


def init_bn() -> jax.Array:
    state = {}
    for li in range(len(LAYERS) - 1):
        n = LAYERS[li][1]
        state[f"mu{li}"] = jnp.zeros((n,), jnp.float32)
        state[f"var{li}"] = jnp.ones((n,), jnp.float32)
    return pack_bn(state)


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------

def _forward(theta, bn, x, *, train: bool, key=None):
    """Returns (efficiency[B], new_bn[S])."""
    p = unpack_theta(theta)
    s = unpack_bn(bn)
    new_s = dict(s)
    h = x
    n_hidden = len(LAYERS) - 1
    for li in range(n_hidden):
        h = fused_dense(h, p[f"w{li}"], p[f"b{li}"], True)  # dense + ReLU
        if train:
            mu = jnp.mean(h, axis=0)
            var = jnp.var(h, axis=0)
            new_s[f"mu{li}"] = (1 - BN_MOMENTUM) * s[f"mu{li}"] + BN_MOMENTUM * mu
            new_s[f"var{li}"] = (1 - BN_MOMENTUM) * s[f"var{li}"] + BN_MOMENTUM * var
        else:
            mu, var = s[f"mu{li}"], s[f"var{li}"]
        h = (h - mu[None, :]) * jax.lax.rsqrt(var[None, :] + BN_EPS)
        h = h * p[f"gamma{li}"][None, :] + p[f"beta{li}"][None, :]
        if train and DROPOUT > 0.0:
            sub = jax.random.fold_in(key, li)
            keep = jax.random.bernoulli(sub, 1.0 - DROPOUT, h.shape)
            h = jnp.where(keep, h / (1.0 - DROPOUT), 0.0)
    li = n_hidden
    h = fused_dense(h, p[f"w{li}"], p[f"b{li}"], False)
    eff = jax.nn.sigmoid(h[:, 0])
    return eff, _pack(new_s, _bn_shapes())


def predict(theta, bn, x):
    """Inference forward: running BN stats, no dropout."""
    eff, _ = _forward(theta, bn, x, train=False)
    return eff


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------

def mape_loss(pred, y):
    return jnp.mean(jnp.abs(pred - y) / jnp.clip(y, 1e-4, None))


def pinball_loss(pred, y, tau: float):
    d = y - pred
    return jnp.mean(jnp.maximum(tau * d, (tau - 1.0) * d))


# ---------------------------------------------------------------------------
# AdamW training step.
# ---------------------------------------------------------------------------

def _loss_fn(theta, bn, x, y, key, tau):
    pred, new_bn = _forward(theta, bn, x, train=True, key=key)
    if tau is None:
        loss = mape_loss(pred, y)
    else:
        loss = pinball_loss(pred, y, tau)
    return loss, new_bn


def train_step(theta, m, v, bn, x, y, step, key, *, tau=None):
    """One AdamW step.  ``step`` is the 1-based step counter (f32 scalar),
    ``key`` a jax.random.PRNGKey (uint32[2]).  Returns
    (theta', m', v', bn', loss)."""
    (loss, new_bn), grad = jax.value_and_grad(_loss_fn, has_aux=True)(
        theta, bn, x, y, key, tau)
    m = BETA1 * m + (1 - BETA1) * grad
    v = BETA2 * v + (1 - BETA2) * grad * grad
    mhat = m / (1 - BETA1 ** step)
    vhat = v / (1 - BETA2 ** step)
    theta = theta - LR * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + WD * theta)
    return theta, m, v, new_bn, loss
