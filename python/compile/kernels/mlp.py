"""Layer-1 Pallas kernels for the SynPerf performance-estimator MLP.

The MLP's compute hot-spot is a chain of dense layers.  Each dense layer is
implemented as a fused Pallas kernel (matmul + bias + optional ReLU) whose
forward AND backward passes are Pallas matmul kernels, wired together with a
``jax.custom_vjp`` so the Layer-2 training step can differentiate through it.

TPU-adaptation notes (DESIGN.md §Hardware-Adaptation):
  * Blocks are row panels over the batch dimension with the full K / N extent
    resident — for the layer sizes used here (<=256x256 fp32) a panel fits
    comfortably in VMEM (<= ~0.5 MB including inputs+outputs).
  * ``interpret=True`` everywhere: real Mosaic lowering emits a TPU
    custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
    portable HLO so the same artifact runs under the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_bm(m: int, cap: int = 128) -> int:
    """Largest power-of-two row-panel size that divides ``m`` (<= cap)."""
    bm = 1
    while bm * 2 <= cap and m % (bm * 2) == 0:
        bm *= 2
    return bm


# ---------------------------------------------------------------------------
# Raw Pallas matmul:  (M, K) @ (K, N) -> (M, N), grid over M row panels.
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Pallas row-panel matmul used by the dense backward pass."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_bm(m)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


# ---------------------------------------------------------------------------
# Fused dense:  act(x @ w + b)  with custom VJP.
# ---------------------------------------------------------------------------


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _dense_forward(x, w, b, relu: bool):
    m, k = x.shape
    _, n = w.shape
    bm = _pick_bm(m)
    return pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_dense(x, w, b, relu: bool = False):
    """act(x @ w + b) as a single fused Pallas kernel (differentiable)."""
    return _dense_forward(x, w, b, relu)


def _fused_dense_fwd(x, w, b, relu):
    y = _dense_forward(x, w, b, relu)
    return y, (x, w, y)


def _fused_dense_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0.0).astype(g.dtype)
    dx = matmul(g, w.T)  # (M, N) @ (N, K)
    dw = matmul(x.T, g)  # (K, M) @ (M, N)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)
