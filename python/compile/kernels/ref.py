"""Pure-jnp oracles for the Pallas kernels in mlp.py.

Used by pytest/hypothesis at build time to validate kernel numerics before
the model is AOT-lowered.  Never shipped to the rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def fused_dense_ref(x, w, b, relu: bool = False):
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y
