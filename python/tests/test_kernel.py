"""Layer-1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes; assert_allclose against the reference is the core
build-time correctness signal before AOT lowering.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 4, 8, 64, 96, 128, 256]),
    k=st.sampled_from([1, 3, 32, 64, 129, 256]),
    n=st.sampled_from([1, 5, 64, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    got = mlp.matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 16, 64, 128, 256, 512]),
    k=st.sampled_from([2, 32, 64, 128]),
    n=st.sampled_from([1, 64, 128, 256]),
    relu=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_fused_dense_matches_ref(m, k, n, relu, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    got = mlp.fused_dense(x, w, b, relu)
    want = ref.fused_dense_ref(x, w, b, relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("m,k,n", [(8, 16, 4), (64, 32, 256), (128, 64, 1)])
def test_fused_dense_gradients_match_ref(relu, m, k, n):
    """custom_vjp backward (Pallas matmuls) equals autodiff of the oracle."""
    x = _rand(7, (m, k))
    w = _rand(8, (k, n))
    b = _rand(9, (n,))

    def loss_pallas(x, w, b):
        return jnp.sum(jnp.tanh(mlp.fused_dense(x, w, b, relu)))

    def loss_ref(x, w, b):
        return jnp.sum(jnp.tanh(ref.fused_dense_ref(x, w, b, relu)))

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_pick_bm_divides():
    for m in [1, 2, 3, 6, 64, 96, 100, 128, 256, 1000, 1024]:
        bm = mlp._pick_bm(m)
        assert m % bm == 0
        assert bm <= 128


def test_dense_kernel_relu_clamps():
    x = -jnp.ones((4, 8), jnp.float32)
    w = jnp.eye(8, dtype=jnp.float32)
    b = jnp.zeros((8,), jnp.float32)
    out = mlp.fused_dense(x, w, b, True)
    assert float(jnp.min(out)) == 0.0
