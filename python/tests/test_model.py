"""Layer-2 model tests: packing, forward shapes/semantics, training step."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


def test_theta_pack_roundtrip():
    theta = model.init_theta(jax.random.PRNGKey(3))
    assert theta.shape == (model.THETA_SIZE,)
    p = model.unpack_theta(theta)
    again = model.pack_theta(p)
    np.testing.assert_array_equal(theta, again)


def test_bn_pack_roundtrip():
    bn = model.init_bn()
    assert bn.shape == (model.BN_SIZE,)
    s = model.unpack_bn(bn)
    np.testing.assert_array_equal(bn, model.pack_bn(s))
    # initial running stats: mu=0, var=1
    assert float(jnp.sum(jnp.abs(s["mu0"]))) == 0.0
    assert float(jnp.min(s["var1"])) == 1.0


def test_predict_shape_and_range():
    theta = model.init_theta(jax.random.PRNGKey(0))
    bn = model.init_bn()
    x = jax.random.normal(jax.random.PRNGKey(1), (17, model.FEATURE_DIM))
    eff = model.predict(theta, bn, x)
    assert eff.shape == (17,)
    assert bool(jnp.all(eff > 0.0)) and bool(jnp.all(eff < 1.0))


def test_predict_deterministic():
    theta = model.init_theta(jax.random.PRNGKey(0))
    bn = model.init_bn()
    x = jax.random.normal(jax.random.PRNGKey(2), (5, model.FEATURE_DIM))
    a = model.predict(theta, bn, x)
    b = model.predict(theta, bn, x)
    np.testing.assert_array_equal(a, b)


def _toy_batch(n=256, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, model.FEATURE_DIM))
    # learnable synthetic efficiency in (0,1)
    y = jax.nn.sigmoid(0.7 * x[:, 0] - 0.3 * x[:, 1] + 0.1)
    return x, y


def test_train_step_reduces_loss():
    theta = model.init_theta(jax.random.PRNGKey(0))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    bn = model.init_bn()
    x, y = _toy_batch()
    step_fn = jax.jit(lambda t, m, v, bn, s, k: model.train_step(
        t, m, v, bn, x, y, s, k, tau=None))
    losses = []
    for i in range(30):
        key = jax.random.PRNGKey(100 + i)
        theta, m, v, bn, loss = step_fn(theta, m, v, bn, jnp.float32(i + 1), key)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_step_updates_bn_running_stats():
    theta = model.init_theta(jax.random.PRNGKey(0))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    bn = model.init_bn()
    x, y = _toy_batch(seed=5)
    theta2, m2, v2, bn2, _ = model.train_step(
        theta, m, v, bn, x, y, jnp.float32(1), jax.random.PRNGKey(0), tau=None)
    assert float(jnp.sum(jnp.abs(bn2 - bn))) > 0.0
    assert float(jnp.sum(jnp.abs(theta2 - theta))) > 0.0


def test_pinball_loss_asymmetry():
    y = jnp.array([0.5])
    lo = model.pinball_loss(jnp.array([0.4]), y, 0.8)   # under-predict
    hi = model.pinball_loss(jnp.array([0.6]), y, 0.8)   # over-predict
    # tau=0.8 penalizes under-prediction 4x more than over-prediction
    assert float(lo) > float(hi)
    np.testing.assert_allclose(float(lo) / float(hi), 4.0, rtol=1e-5)


def test_mape_loss_zero_at_perfect():
    y = jnp.array([0.2, 0.6, 0.9])
    assert float(model.mape_loss(y, y)) == 0.0


def test_p80_training_biases_high():
    """Quantile tau=0.8 model should predict above the median of noisy data."""
    theta = model.init_theta(jax.random.PRNGKey(0))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    bn = model.init_bn()
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (256, model.FEATURE_DIM))
    base = jax.nn.sigmoid(0.5 * x[:, 0])
    noise = 0.3 * jax.random.uniform(jax.random.PRNGKey(7), (256,))
    y = jnp.clip(base - noise, 0.01, 0.99)  # noisy, mostly below ceiling
    step_fn = jax.jit(lambda t, m, v, bn, s, k: model.train_step(
        t, m, v, bn, x, y, s, k, tau=0.8))
    for i in range(150):
        theta, m, v, bn, loss = step_fn(
            theta, m, v, bn, jnp.float32(i + 1), jax.random.PRNGKey(i))
    pred = model.predict(theta, bn, x)
    frac_above = float(jnp.mean((pred >= y).astype(jnp.float32)))
    assert frac_above > 0.6, frac_above
