"""AOT lowering smoke tests: HLO text artifacts parse and carry the right
parameter count; manifest matches the model constants."""

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_lower_fwd_b1_is_hlo_text():
    text = aot.lower_fwd(1)
    assert "HloModule" in text
    assert "parameter(0)" in text


def test_lower_fwd_shapes_mentioned():
    text = aot.lower_fwd(64)
    assert f"f32[{model.THETA_SIZE}]" in text
    assert f"f32[64,{model.FEATURE_DIM}]" in text


def test_lower_train_has_all_args():
    text = aot.lower_train(256, None)
    assert "HloModule" in text
    # 8 parameters: theta, m, v, bn, x, y, step, key
    for i in range(8):
        assert f"parameter({i})" in text


def test_train_mape_vs_p80_differ():
    a = aot.lower_train(256, None)
    b = aot.lower_train(256, 0.8)
    assert a != b
